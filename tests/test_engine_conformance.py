"""Cross-engine conformance matrix: the acceptance gate for the step-engine
substrate.

Every cell of (engine x map backend x paper domain) must produce the same
trajectory to 1e-5 on a FIXED iteration budget (tolerances 0 so no lane
terminates early — this compares trajectories, not "two different converged
points").  The three engines run the SAME mathematical operator through
three executions:

  * ``matvec``           — the domain's own K_mv/KT_mv callables, vmapped
  * ``fused_structured`` — the ELL index metadata the domain attaches
                           (``StructuredOperator``), via the batched
                           gather/segment-reduce kernels
  * ``fused``            — the densified K (``structured_to_dense``)
                           through the blocked matmul kernels

so a pass pins the index metadata against the domain callables AND against
an explicit dense materialisation, across every execution backend
(ragged/padded k included) and for warm-started runs.

Also home to the in-loop-KKT regression gate: ``kkt="inloop"`` (free
convergence checks from carried products) must match ``kkt="standalone"``
(fresh operator passes per check) BIT-level on the CPU/XLA path — proof
the carried products never drift through restarts, lane freezing, or warm
starts.
"""

import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _subproc import repro_env
from repro.core import backends as backends_mod
from repro.core import pdhg, pop
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload
from repro.problems.load_balancing import (LoadBalanceProblem,
                                           make_shard_workload,
                                           _k_mv as lb_k_mv,
                                           _kt_mv as lb_kt_mv)
from repro.problems.traffic_engineering import (TrafficProblem, k_shortest_paths,
                                                make_demands, make_topology)

# fixed-budget settings: tol 0 => every lane runs max_iters exactly
FIXED_KW = dict(max_iters=120, check_every=40, tol_primal=0.0, tol_gap=0.0)

ENGINES = ("matvec", "fused", "fused_structured")
BACKENDS = sorted(backends_mod.MAP_BACKENDS)
DOMAINS = ("cluster", "traffic", "balance")


def _cluster_case():
    # 16 jobs over k=3 lanes: ragged slot padding (6/5/5)
    wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    p = pop.plan(prob, 3, strategy="stratified")
    return pop.build(prob, p), prob.K_mv, prob.KT_mv


def _traffic_case():
    topo = make_topology(24, 48, seed=1)
    pairs, dem = make_demands(topo, 14, seed=1)
    pe = k_shortest_paths(topo, pairs, n_paths=3, max_len=12, seed=1)
    prob = TrafficProblem(topo, pairs, dem, pe)
    p = pop.plan(prob, 3, strategy="stratified")
    return pop.build(prob, p), prob.K_mv, prob.KT_mv


def _balance_case():
    # the LB domain split: server groups, shards follow their server —
    # ragged shard counts per lane, padded to n_pad
    wl = make_shard_workload(18, 6, seed=2)
    prob = LoadBalanceProblem(wl)
    groups = [np.arange(6)[i::3] for i in range(3)]
    shard_sets = [np.flatnonzero(np.isin(wl.placement, g)) for g in groups]
    n_pad = max(len(s) for s in shard_sets)
    ops = pdhg.stack_ops([prob._relax_op(s, g, n_pad, 2, structured=True)
                          for s, g in zip(shard_sets, groups)])
    return ops, lb_k_mv, lb_kt_mv


_CASES = {"cluster": _cluster_case, "traffic": _traffic_case,
          "balance": _balance_case}


@pytest.fixture(scope="module")
def cells():
    """domain -> (structured ops, densified ops, K_mv, KT_mv, reference)."""
    out = {}
    for name, build in _CASES.items():
        ops, k_mv, kt_mv = build()
        assert ops.structured is not None, name
        dense = ops._replace(data=(pdhg.structured_to_dense(ops.structured),),
                             structured=None)
        ref = backends_mod.solve_map(ops, k_mv, kt_mv, FIXED_KW,
                                     backend="vmap", engine="matvec")
        out[name] = (ops, dense, k_mv, kt_mv, ref)
    return out


def _engine_inputs(cells, domain, engine):
    ops, dense, k_mv, kt_mv, ref = cells[domain]
    if engine == "fused":
        return dense, pdhg.dense_K_mv, pdhg.dense_KT_mv, ref
    return ops, k_mv, kt_mv, ref


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("domain", DOMAINS)
def test_conformance_matrix(domain, engine, backend, cells):
    """ISSUE acceptance: every engine x backend x domain cell agrees with
    the matvec/vmap reference to 1e-5 at a fixed budget.  chunked_vmap
    runs chunk=2 so k=3 exercises the ragged-k padding path."""
    ops, k_mv, kt_mv, ref = _engine_inputs(cells, domain, engine)
    opts = {"chunk": 2} if backend == "chunked_vmap" else {}
    r = backends_mod.solve_map(ops, k_mv, kt_mv, FIXED_KW,
                               backend=backend, engine=engine, **opts)
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r.y), np.asarray(ref.y),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r.iterations),
                                  np.asarray(ref.iterations))


@pytest.mark.parametrize("domain", DOMAINS)
def test_conformance_warm_started(domain, cells):
    """Warm-started runs stay in conformance: every engine seeded with the
    same previous iterates produces the same (fixed-budget) trajectory."""
    ops, _, k_mv, kt_mv, _ = cells[domain]
    seed = backends_mod.solve_map(ops, k_mv, kt_mv,
                                  dict(FIXED_KW, max_iters=80),
                                  backend="vmap", engine="matvec")
    warm = (seed.x, seed.y)
    results = {}
    for engine in ENGINES:
        e_ops, e_km, e_ktm, _ = _engine_inputs(cells, domain, engine)
        results[engine] = backends_mod.solve_map(
            e_ops, e_km, e_ktm, FIXED_KW, backend="vmap", engine=engine,
            warm=warm)
    for engine in ("fused", "fused_structured"):
        np.testing.assert_allclose(np.asarray(results[engine].x),
                                   np.asarray(results["matvec"].x),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(results[engine].y),
                                   np.asarray(results["matvec"].y),
                                   rtol=1e-5, atol=1e-5)


def test_auto_picks_structured_when_metadata_present(cells):
    ops, _, _, _, _ = cells["cluster"]
    assert pdhg.select_engine(ops, GavelProblem.K_mv,
                              GavelProblem.KT_mv) == "fused_structured"
    bare = ops._replace(structured=None)
    assert pdhg.select_engine(bare, GavelProblem.K_mv,
                              GavelProblem.KT_mv) == "matvec"
    with pytest.raises(ValueError, match="fused_structured"):
        pdhg.resolve_engine("fused_structured", bare)


def test_conformance_multi_device_subprocess():
    """Ragged k on a real multi-device mesh: k=3 on a forced 4-device host
    pads to 4 lanes in shard_map/pmap; the structured engine must ride the
    padded batch unchanged (index arrays replicate like any other leaf)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        assert jax.device_count() == 4, jax.device_count()
        from repro.core import backends as backends_mod, pop
        from repro.problems.cluster_scheduling import (GavelProblem,
                                                       make_cluster_workload)
        wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
        prob = GavelProblem(wl, space_sharing=False)
        p = pop.plan(prob, 3, strategy="stratified")
        ops = pop.build(prob, p)
        kw = dict(max_iters=120, check_every=40, tol_primal=0.0, tol_gap=0.0)
        ref = backends_mod.solve_map(ops, prob.K_mv, prob.KT_mv, kw,
                                     backend="vmap", engine="matvec")
        for backend in ("shard_map", "pmap"):
            for engine in ("matvec", "fused_structured"):
                r = backends_mod.solve_map(ops, prob.K_mv, prob.KT_mv, kw,
                                           backend=backend, engine=engine)
                np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                           rtol=1e-5, atol=1e-5)
        print("multi-device conformance ok")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=repro_env())
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "multi-device conformance ok" in r.stdout


# ---------------------------------------------------------------------------
# in-loop KKT regression gate (ISSUE satellite): fused-KKT == standalone-KKT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_inloop_kkt_matches_standalone_bitwise(engine, cells):
    """The in-loop KKT path (convergence checks from carried products, zero
    extra operator passes) must report the same residuals, iteration counts
    and restart points as the standalone reference (fresh K/K^T passes per
    check) — bit-level on the CPU/XLA path.  Real tolerances + small
    check_every so early termination, lane freezing and adaptive restarts
    are all exercised."""
    ops, k_mv, kt_mv, _ = _engine_inputs(cells, "cluster", engine)
    kw = dict(max_iters=2_000, check_every=20, tol_primal=1e-4, tol_gap=1e-4)
    r_in = pdhg.solve_stacked(ops, engine=engine, K_mv=k_mv, KT_mv=kt_mv,
                              kkt="inloop", **kw)
    r_ref = pdhg.solve_stacked(ops, engine=engine, K_mv=k_mv, KT_mv=kt_mv,
                               kkt="standalone", **kw)
    assert bool(np.asarray(r_in.converged).all())
    exact = jax.default_backend() != "tpu"
    cmp = (np.testing.assert_array_equal if exact
           else lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6,
                                                        atol=1e-6))
    cmp(np.asarray(r_in.x), np.asarray(r_ref.x))
    cmp(np.asarray(r_in.y), np.asarray(r_ref.y))
    cmp(np.asarray(r_in.primal_res), np.asarray(r_ref.primal_res))
    cmp(np.asarray(r_in.gap), np.asarray(r_ref.gap))
    np.testing.assert_array_equal(np.asarray(r_in.iterations),
                                  np.asarray(r_ref.iterations))
    np.testing.assert_array_equal(np.asarray(r_in.n_restarts),
                                  np.asarray(r_ref.n_restarts))


def test_inloop_kkt_warm_masked_bitwise(cells):
    """The carried-product bookkeeping survives masked warm starts (the
    churn path): in-loop == standalone bit-level there too."""
    ops, k_mv, kt_mv, ref = cells["cluster"][0], cells["cluster"][2], \
        cells["cluster"][3], cells["cluster"][4]
    rng = np.random.default_rng(0)
    wx = jnp.asarray(rng.uniform(0, 1, np.asarray(ops.c).shape), jnp.float32)
    wy = jnp.asarray(rng.uniform(0, 1, np.asarray(ops.q).shape), jnp.float32)
    mask = jnp.asarray([True, False, True])
    kw = dict(max_iters=1_000, check_every=20, tol_primal=1e-4, tol_gap=1e-4)
    r_in = pdhg.solve_stacked(ops, engine="fused_structured", warm_x=wx,
                              warm_y=wy, warm_mask=mask, kkt="inloop", **kw)
    r_ref = pdhg.solve_stacked(ops, engine="fused_structured", warm_x=wx,
                               warm_y=wy, warm_mask=mask, kkt="standalone",
                               **kw)
    if jax.default_backend() != "tpu":
        np.testing.assert_array_equal(np.asarray(r_in.x), np.asarray(r_ref.x))
        np.testing.assert_array_equal(np.asarray(r_in.primal_res),
                                      np.asarray(r_ref.primal_res))
    np.testing.assert_array_equal(np.asarray(r_in.iterations),
                                  np.asarray(r_ref.iterations))
    np.testing.assert_array_equal(np.asarray(r_in.n_restarts),
                                  np.asarray(r_ref.n_restarts))


def test_unknown_kkt_mode_rejected():
    ops, k_mv, kt_mv = _cluster_case()
    with pytest.raises(ValueError, match="kkt mode"):
        pdhg.solve_stacked(ops, engine="matvec", kkt="telepathy")


# ---------------------------------------------------------------------------
# observability: results must report the backend/engine that ACTUALLY ran
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", ("matvec", "fused_structured"))
def test_reported_execution_matches_forced_cell(backend, engine):
    """Every forced (engine x backend) cell must come back on the
    POPResult verbatim — the resolution layer may not silently substitute."""
    wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    from repro.core.config import ExecConfig, SolveConfig
    opts = {"chunk": 2} if backend == "chunked_vmap" else {}
    res = pop.solve_instance(
        prob, SolveConfig(k=3, strategy="stratified"),
        ExecConfig(backend=backend, engine=engine,
                   solver_kw=FIXED_KW, backend_opts=opts))
    assert res.backend == backend
    assert res.engine == engine
    assert res.plan_source == "fresh"


def test_reported_execution_resolves_auto():
    """backend="auto"/engine="auto" must be REPORTED as the concrete
    resolution, never echoed back as "auto" — the observability gap this
    PR closes."""
    wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    from repro.core.config import SolveConfig
    res = pop.solve_instance(prob, SolveConfig(k=3, strategy="stratified"))
    assert res.backend in backends_mod.MAP_BACKENDS
    assert res.engine in ("matvec", "fused", "fused_structured")
    # Gavel singleton combos carry StructuredOperator metadata -> auto
    # must pick the structured-fused engine (pinned by
    # test_auto_picks_structured_when_metadata_present at solve_map level)
    assert res.engine == "fused_structured"
    from repro.core.config import ExecConfig as _EC
    full = pop.solve_full_ex(prob, exec_cfg=_EC(solver_kw=dict(FIXED_KW)))
    assert full.backend in backends_mod.MAP_BACKENDS
    assert full.engine == "fused_structured"


# ---------------------------------------------------------------------------
# blocked-full engine (fused_structured_full) + mixed-precision ELL storage
# ---------------------------------------------------------------------------

def _full_case(domain):
    """Single-lane FULL op (fold maps attached) + the domain callables."""
    if domain == "cluster":
        wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
        prob = GavelProblem(wl, space_sharing=False)
        return prob.build_full(), prob.K_mv, prob.KT_mv
    if domain == "traffic":
        topo = make_topology(24, 48, seed=1)
        pairs, dem = make_demands(topo, 14, seed=1)
        pe = k_shortest_paths(topo, pairs, n_paths=3, max_len=12, seed=1)
        prob = TrafficProblem(topo, pairs, dem, pe)
        return prob.build_full(), prob.K_mv, prob.KT_mv
    wl = make_shard_workload(18, 6, seed=2)
    prob = LoadBalanceProblem(wl)
    op = prob._relax_op(np.arange(18), np.arange(6), 18, 6, structured=True)
    return op, lb_k_mv, lb_kt_mv


@pytest.fixture(scope="module")
def full_cells():
    out = {}
    for name in DOMAINS:
        op, k_mv, kt_mv = _full_case(name)
        assert op.structured is not None, name
        assert op.structured.row_fold is not None, name
        ref, _, eng = backends_mod.solve_one_ex(op, k_mv, kt_mv, FIXED_KW,
                                                backend="vmap",
                                                engine="matvec")
        assert eng == "matvec"
        out[name] = (op, k_mv, kt_mv, ref)
    return out


@pytest.mark.parametrize("domain", DOMAINS)
def test_full_engine_matches_matvec(domain, full_cells):
    """ISSUE acceptance: the M-blocked streaming engine agrees with the
    domain matvec reference to 1e-5 at the fixed budget, on the full
    (single-lane, unpartitioned) problem of all three structured
    domains."""
    op, k_mv, kt_mv, ref = full_cells[domain]
    r, _, eng = backends_mod.solve_one_ex(op, k_mv, kt_mv, FIXED_KW,
                                          backend="vmap",
                                          engine="fused_structured_full")
    assert eng == "fused_structured_full"
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r.y), np.asarray(ref.y),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r.iterations),
                                  np.asarray(ref.iterations))


def test_full_engine_auto_threshold(full_cells, monkeypatch):
    """auto takes the blocked-full engine exactly when the operator is
    single-lane, carries fold maps, and its wide buckets store >=
    FULL_ENGINE_MIN_WIDE_ELEMS elements."""
    op, k_mv, kt_mv, _ = full_cells["traffic"]
    opb = jax.tree.map(lambda a: jnp.asarray(a)[None], op)
    # small problem: below the threshold -> lane engine
    assert pdhg.select_engine(opb, k_mv, kt_mv) == "fused_structured"
    # force the threshold down: the same op now takes the streaming engine
    monkeypatch.setattr(pdhg, "FULL_ENGINE_MIN_WIDE_ELEMS", 1)
    assert pdhg.select_engine(opb, k_mv, kt_mv) == "fused_structured_full"
    # a k=3 stack is never eligible, whatever its size
    ops3 = jax.tree.map(lambda a: jnp.concatenate([a[None]] * 3), op)
    assert pdhg.select_engine(ops3, k_mv, kt_mv) == "fused_structured"
    # and the engine refuses an operator without fold maps
    bare = opb._replace(structured=opb.structured._replace(
        row_fold=None, col_fold=None))
    assert pdhg.select_engine(bare, k_mv, kt_mv) == "fused_structured"
    with pytest.raises(ValueError, match="fold"):
        pdhg.resolve_engine("fused_structured_full", bare)


@pytest.mark.parametrize("coef_dtype", ("float32", "bfloat16", "int8"))
def test_full_kernel_interpret_matches_ref(coef_dtype, full_cells):
    """The Pallas kernel bodies (interpret mode — runs the real kernels on
    CPU) match the ragged XLA reference, with deliberately small block
    overrides so the traffic case exercises multiple narrow/wide phases
    and the ragged last-block padding of every grid axis."""
    from repro.kernels import ops as kops
    op, _, _, _ = full_cells["traffic"]
    s = op.structured
    if coef_dtype != "float32":
        s = pdhg.quantize_structured(s, coef_dtype)
    sb = jax.tree.map(lambda a: jnp.asarray(a)[None], s)
    plan = pdhg._wide_block_plan(s.wrow_val)
    cplan = pdhg._wide_block_plan(s.wcol_val)
    M, N = s.row_idx.shape[-1], s.col_idx.shape[-1]
    rng = np.random.default_rng(7)
    f = lambda shape: jnp.asarray(rng.standard_normal(shape), jnp.float32)
    x, c, kty = f((1, N)), f((1, N)), f((1, N))
    l, u = jnp.zeros((1, N)), jnp.full((1, N), 10.0)
    tau = jnp.full((1,), 0.3)
    kw = dict(block_m=128, block_w=8, block_d=128)
    xn_i, kx_i = kops.structured_full_forward_step(
        sb, x, c, l, u, tau, kty, plan=plan, backend="interpret", **kw)
    xn_r, kx_r = kops.structured_full_forward_step(
        sb, x, c, l, u, tau, kty, plan=plan, backend="xla")
    np.testing.assert_allclose(np.asarray(xn_i), np.asarray(xn_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kx_i), np.asarray(kx_r),
                               rtol=1e-5, atol=1e-5)
    y, q = f((1, M)), f((1, M))
    kxn, kxp = f((1, M)), f((1, M))
    mask = jnp.ones((1, M), jnp.float32)
    sigma = jnp.full((1,), 0.2)
    yn_i, kty_i = kops.structured_full_backward_step(
        sb, y, q, sigma, mask, kxn, kxp, plan=cplan, backend="interpret",
        **kw)
    yn_r, kty_r = kops.structured_full_backward_step(
        sb, y, q, sigma, mask, kxn, kxp, plan=cplan, backend="xla")
    np.testing.assert_allclose(np.asarray(yn_i), np.asarray(yn_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kty_i), np.asarray(kty_r),
                               rtol=1e-5, atol=1e-5)


# --- mixed-precision ELL storage ------------------------------------------

@pytest.mark.parametrize("coef_dtype,tol", (("bfloat16", 1e-2),
                                            ("int8", 1e-2)))
def test_quantize_roundtrip(coef_dtype, tol, full_cells):
    """quantize -> dequantize reproduces the f32 coefficients within the
    documented storage tolerance (bf16: 8-bit mantissa ~ 0.4% rel; int8:
    symmetric per-bucket scale ~ 0.4% of the bucket max)."""
    op, _, _, _ = full_cells["cluster"]
    s = op.structured
    q = pdhg.quantize_structured(s, coef_dtype)
    assert q.coef_dtype == coef_dtype
    back = pdhg.dequantize_structured(q)
    assert back.coef_dtype == "float32" and back.row_scale is None
    for a, b in ((s.row_val, back.row_val), (s.wrow_val, back.wrow_val),
                 (s.col_val, back.col_val), (s.wcol_val, back.wcol_val)):
        scale = max(float(jnp.max(jnp.abs(a))), 1e-30)
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=tol * scale)
    with pytest.raises(ValueError, match="already stores"):
        pdhg.quantize_structured(q, "int8")


@pytest.mark.parametrize("coef_dtype,tol", (("bfloat16", 1e-2),
                                            ("int8", 1e-2)))
def test_quantized_matvec_within_tolerance(coef_dtype, tol, full_cells):
    """Both full-path matvec directions through quantized storage agree
    with f32 storage to the documented relative tolerance."""
    from repro.kernels import ops as kops
    op, _, _, _ = full_cells["cluster"]
    s = op.structured
    sb = jax.tree.map(lambda a: jnp.asarray(a)[None], s)
    qb = jax.tree.map(lambda a: jnp.asarray(a)[None],
                      pdhg.quantize_structured(s, coef_dtype))
    M, N = s.row_idx.shape[-1], s.col_idx.shape[-1]
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, N)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((1, M)), jnp.float32)
    kx_f, kx_q = kops.smatvec_full(sb, x), kops.smatvec_full(qb, x)
    kty_f, kty_q = kops.smatvec_t_full(sb, y), kops.smatvec_t_full(qb, y)
    ref_scale = float(jnp.max(jnp.abs(kx_f))) + 1e-30
    np.testing.assert_allclose(np.asarray(kx_q), np.asarray(kx_f),
                               atol=tol * ref_scale)
    ref_scale = float(jnp.max(jnp.abs(kty_f))) + 1e-30
    np.testing.assert_allclose(np.asarray(kty_q), np.asarray(kty_f),
                               atol=tol * ref_scale)


def test_int8_exact_for_uniform_coefficients(full_cells):
    """Traffic coefficients are all 1.0 (path-on-edge indicators), so int8
    storage is EXACT: the full-engine solve trajectory matches f32 storage
    bit-for-bit on the fixed budget."""
    op, k_mv, kt_mv, _ = full_cells["traffic"]
    q = op._replace(structured=pdhg.quantize_structured(op.structured,
                                                        "int8"))
    r_f, _, _ = backends_mod.solve_one_ex(op, k_mv, kt_mv, FIXED_KW,
                                          backend="vmap",
                                          engine="fused_structured_full")
    r_q, _, eng = backends_mod.solve_one_ex(q, k_mv, kt_mv, FIXED_KW,
                                            backend="vmap",
                                            engine="fused_structured_full")
    assert eng == "fused_structured_full"
    np.testing.assert_array_equal(np.asarray(r_q.x), np.asarray(r_f.x))
    np.testing.assert_array_equal(np.asarray(r_q.y), np.asarray(r_f.y))


def test_scale_structured_dequantizes_first(full_cells):
    """Equilibration on quantized storage degrades to f32 (scaled products
    are not int8-representable) and matches scaling the dequantized
    operator exactly — the scales round-trip, they never compose with
    the diagonal scaling."""
    op, _, _, _ = full_cells["cluster"]
    sb = jax.tree.map(lambda a: jnp.asarray(a)[None], op.structured)
    qb = jax.tree.map(lambda a: jnp.asarray(a)[None],
                      pdhg.quantize_structured(op.structured, "int8"))
    M, N = op.structured.row_idx.shape[-1], op.structured.col_idx.shape[-1]
    rng = np.random.default_rng(5)
    d_r = jnp.asarray(rng.uniform(0.5, 2.0, (1, M)), jnp.float32)
    d_c = jnp.asarray(rng.uniform(0.5, 2.0, (1, N)), jnp.float32)
    scaled_q = pdhg.scale_structured(qb, d_r, d_c)
    scaled_f = pdhg.scale_structured(
        jax.tree.map(lambda a: a, pdhg.dequantize_structured(qb)), d_r, d_c)
    assert scaled_q.coef_dtype == "float32"
    assert scaled_q.row_scale is None
    np.testing.assert_array_equal(np.asarray(scaled_q.row_val),
                                  np.asarray(scaled_f.row_val))
    np.testing.assert_array_equal(np.asarray(scaled_q.wcol_val),
                                  np.asarray(scaled_f.wcol_val))
    # and the scaled-from-quantized operator stays close to scaling the
    # ORIGINAL f32 payload (within the storage tolerance)
    scaled_orig = pdhg.scale_structured(sb, d_r, d_c)
    ref_scale = float(jnp.max(jnp.abs(scaled_orig.row_val))) + 1e-30
    np.testing.assert_allclose(np.asarray(scaled_q.row_val),
                               np.asarray(scaled_orig.row_val),
                               atol=1e-2 * ref_scale)
