"""Subprocess smoke tests for ``examples/`` — the de-facto API docs.

Each example runs end-to-end in its fast mode in a child process (so a
surface change that breaks an example fails tier-1 loudly instead of
rotting silently) and must print its closing marker line."""

import os
import subprocess
import sys

import pytest

from _subproc import repro_env

EXAMPLES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples")

CASES = [
    # (script, args, marker expected in stdout)
    ("quickstart.py", ["--fast"], "MoE place"),
    ("schedule_cluster.py", ["--fast"], "service stats"),
    ("serve_balanced.py", ["--fast"], "decoded"),
    ("train_e2e.py", ["--steps", "8", "--fail-at", "4",
                      "--ckpt-every", "2"], "across restart"),
]


@pytest.mark.parametrize("script,args,marker",
                         CASES, ids=[c[0] for c in CASES])
def test_example_runs(script, args, marker):
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, script), *args],
        env=repro_env(), capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"{script} failed\n--- stdout ---\n{proc.stdout[-3000:]}\n"
        f"--- stderr ---\n{proc.stderr[-3000:]}")
    assert marker in proc.stdout, (
        f"{script} did not print {marker!r}\n{proc.stdout[-2000:]}")
