"""Tier-1 gate on the public API surface.

A fresh render of the exported names + signatures must match the
committed snapshot (``docs/api_surface.txt``).  Intentional surface
changes regenerate it (``make api-snapshot``) and commit the diff — the
gate exists so the diff SHOWS UP, not to freeze the API forever."""

import difflib
import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_script():
    spec = importlib.util.spec_from_file_location(
        "api_surface", REPO / "scripts" / "api_surface.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_api_surface_matches_snapshot():
    mod = _load_script()
    fresh = mod.render()
    snapshot_path = REPO / "docs" / "api_surface.txt"
    assert snapshot_path.exists(), (
        "docs/api_surface.txt is missing — run `make api-snapshot`")
    committed = snapshot_path.read_text()
    if fresh != committed:
        diff = "\n".join(difflib.unified_diff(
            committed.splitlines(), fresh.splitlines(),
            "docs/api_surface.txt (committed)", "fresh render", lineterm=""))
        raise AssertionError(
            "public API surface drifted from the committed snapshot.\n"
            "If intentional: run `make api-snapshot` and commit the diff.\n"
            + diff)


def test_snapshot_covers_new_surface():
    """The snapshot must pin the redesigned entry points by name."""
    text = (REPO / "docs" / "api_surface.txt").read_text()
    for needle in ("repro.service.PopService", "PopSession.step",
                   "repro.domains.register", "repro.core.config.SolveConfig",
                   "repro.core.config.ExecConfig",
                   "repro.core.solve_instance"):
        assert needle in text, f"{needle} missing from api_surface.txt"
