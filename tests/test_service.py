"""PopService/PopSession + config layer: the redesigned public surface.

Covers config validation/hashability, session warm-state chaining across
instance drift and entity churn, the k=1 full-problem path, tenant
isolation, and the observability contract (resolved backend/engine +
plan-cache verdicts + service-level aggregation)."""

import numpy as np
import pytest

from repro.core import ExecConfig, SolveConfig
from repro.domains import (BalanceInstance, GavelInstance,
                           make_placement_instance)
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import PopService

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


def _traffic(n=24, seed=0, scale=1.0):
    topo = make_topology(20, 40, seed=seed)
    pairs, dem = make_demands(topo, n, seed=seed)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
    return TrafficProblem(topo, pairs, dem * scale, pe)


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

class TestConfigs:
    def test_frozen_and_hashable(self):
        a = ExecConfig(solver_kw=dict(max_iters=100), backend_opts=dict(chunk=4))
        b = ExecConfig(solver_kw=dict(max_iters=100), backend_opts=dict(chunk=4))
        assert a == b and hash(a) == hash(b)
        assert a.solver_dict() == {"max_iters": 100}
        assert a.opts_dict() == {"chunk": 4}
        with pytest.raises(Exception):
            a.backend = "vmap"                      # frozen
        assert hash(SolveConfig(k=3)) == hash(SolveConfig(k=3))

    def test_validated_at_construction(self):
        with pytest.raises(ValueError, match="unknown backend"):
            ExecConfig(backend="warp_drive")
        with pytest.raises(ValueError, match="unknown engine"):
            ExecConfig(engine="warp_drive")
        with pytest.raises(ValueError, match="solver_kw"):
            ExecConfig(solver_kw=dict(max_itres=5))
        with pytest.raises(ValueError, match="strategy"):
            SolveConfig(strategy="psychic")
        with pytest.raises(ValueError, match="k must be"):
            SolveConfig(k=0)
        with pytest.raises(ValueError, match="min_per_sub"):
            SolveConfig(min_per_sub=0)
        with pytest.raises(ValueError, match="replicate_threshold"):
            SolveConfig(replicate_threshold=-1.0)

    def test_k_for_clamps(self):
        assert SolveConfig(k=8, min_per_sub=8).k_for(100) == 8
        assert SolveConfig(k=8, min_per_sub=8).k_for(40) == 5
        assert SolveConfig(k=8, min_per_sub=8).k_for(7) == 1
        assert SolveConfig(k=8).k_for(3) == 3


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------

class TestSession:
    def test_warm_chain_and_plan_cache(self):
        svc = PopService()
        prob = _traffic()
        sess = svc.session("t", prob, solve=SolveConfig(k=3),
                           exec=ExecConfig(solver_kw=KW))
        a1 = sess.step(prob)
        assert a1.plan_cache == "miss" and a1.warm_fraction is None
        a2 = sess.step(_traffic(scale=1.05))
        assert a2.plan_cache == "hit" and a2.warm_fraction == 1.0
        assert a2.step == 1 and sess.steps == 2
        st = sess.stats
        assert st["plan_hits"] == 1 and st["plan_misses"] == 1

    def test_churn_repairs_plan(self):
        svc = PopService()
        wl = make_cluster_workload(32, seed=0)
        ids = np.arange(32)
        sess = svc.session("fleet", domain="gavel",
                           solve=SolveConfig(k=2, strategy="stratified"),
                           exec=ExecConfig(solver_kw=KW))
        sess.step(GavelInstance(wl, job_ids=ids))
        # 4 jobs leave, 4 arrive
        wl2 = make_cluster_workload(32, seed=1)
        ids2 = np.concatenate([ids[4:], 100 + np.arange(4)])
        a = sess.step(GavelInstance(wl2, job_ids=ids2))
        assert a.plan_cache == "repair"
        assert 0.5 < a.warm_fraction < 1.0          # survivors warm

    def test_full_path_small_instance(self):
        svc = PopService()
        wl = make_cluster_workload(12, seed=0)
        sess = svc.session("tiny", domain="gavel",
                           solve=SolveConfig(k=8, min_per_sub=8),
                           exec=ExecConfig(solver_kw=KW))
        a1 = sess.step(GavelInstance(wl, job_ids=np.arange(12)))
        assert a1.plan_cache == "full" and a1.k == 1
        assert a1.warm_fraction is None
        a2 = sess.step(GavelInstance(wl, job_ids=np.arange(12)))
        assert a2.plan_cache == "full" and a2.warm_fraction == 1.0
        # identity change drops the full-path warm start (row misalignment)
        ids3 = np.arange(12).copy(); ids3[[0, 1]] = [1, 0]
        a3 = sess.step(GavelInstance(wl, job_ids=ids3))
        assert a3.warm_fraction is None

    def test_observability_concrete(self):
        svc = PopService()
        inst = make_placement_instance(48, 6, seed=0)
        a = svc.session("m", inst, exec=ExecConfig(solver_kw=KW)).step(inst)
        assert a.backend not in (None, "auto")
        assert a.engine not in (None, "auto")
        assert a.domain == "moe_placement" and a.tenant == "m"
        assert a.iterations > 0 and a.solve_time_s > 0
        assert a.objective == a.metrics["objective"]

    def test_tenant_isolation_and_reentry(self):
        svc = PopService()
        p1, p2 = _traffic(seed=0), _traffic(seed=1)
        s1 = svc.session("a", p1, exec=ExecConfig(solver_kw=KW),
                         solve=SolveConfig(k=2))
        s2 = svc.session("b", p2, exec=ExecConfig(solver_kw=KW),
                         solve=SolveConfig(k=2))
        s1.step(p1)
        assert s2._warm is None                     # b untouched by a
        assert svc.session("a") is s1               # re-entry by name
        # re-entry with the SAME explicit configs is idempotent; a
        # DIFFERENT explicit config must not be silently ignored
        assert svc.session("a", solve=SolveConfig(k=2)) is s1
        with pytest.raises(ValueError, match="pinned"):
            svc.session("a", solve=SolveConfig(k=16))
        with pytest.raises(ValueError, match="pinned"):
            svc.session("a", exec=ExecConfig(backend="serial"))
        assert svc.tenants() == ("a", "b")
        with pytest.raises(ValueError, match="cannot switch"):
            svc.session("a", make_placement_instance(16, 4))
        svc.end_session("a")
        assert svc.tenants() == ("b",)

    def test_session_needs_domain_or_instance(self):
        svc = PopService()
        with pytest.raises(ValueError, match="needs an instance"):
            svc.session("nobody")
        with pytest.raises(ValueError, match="no registered domain"):
            svc.session("x", object())
        with pytest.raises(KeyError, match="unknown domain"):
            svc.session("x", domain="warp_drive")

    def test_seed_restores_generic_pop_state(self):
        """seed() must restore warm state for generic (pipeline) domains
        too, inferring the pop mode from the POPResult type."""
        svc = PopService()
        prob = _traffic()
        s1 = svc.session("orig", prob, solve=SolveConfig(k=3),
                         exec=ExecConfig(solver_kw=KW))
        a1 = s1.step(prob)
        s2 = svc.session("restored", prob, solve=SolveConfig(k=3),
                         exec=ExecConfig(solver_kw=KW))
        s2.seed(a1.raw)                      # POPResult -> "pop" inferred
        a2 = s2.step(prob)
        assert a2.plan_cache == "hit" and a2.warm_fraction == 1.0

    def test_seed_full_state_needs_entity_ids(self):
        """Restoring k=1 full-path state warms only when the caller names
        the ids the iterates are FOR; without them it safely cold-starts."""
        svc = PopService()
        wl = make_cluster_workload(12, seed=0)
        ids = np.arange(12)
        s1 = svc.session("tiny", domain="gavel",
                         solve=SolveConfig(k=8, min_per_sub=8),
                         exec=ExecConfig(solver_kw=KW))
        a1 = s1.step(GavelInstance(wl, job_ids=ids))
        assert a1.plan_cache == "full"
        s2 = svc.session("tiny2", domain="gavel",
                         solve=SolveConfig(k=8, min_per_sub=8),
                         exec=ExecConfig(solver_kw=KW))
        s2.seed(a1.raw, entity_ids=ids)      # FullResult -> "full" inferred
        a2 = s2.step(GavelInstance(wl, job_ids=ids))
        assert a2.warm_fraction == 1.0
        s3 = svc.session("tiny3", domain="gavel",
                         solve=SolveConfig(k=8, min_per_sub=8),
                         exec=ExecConfig(solver_kw=KW))
        s3.seed(a1.raw)                      # no ids -> safe cold start
        a3 = s3.step(GavelInstance(wl, job_ids=ids))
        assert a3.warm_fraction is None

    def test_seed_full_state_positional_domain(self):
        """Domains without an entity_ids hook restore full-path state by
        passing the entity COUNT as the alignment key."""
        svc = PopService()
        prob = _traffic(n=10)
        cfg = dict(solve=SolveConfig(k=1), exec=ExecConfig(solver_kw=KW))
        a1 = svc.session("p1", prob, **cfg).step(prob)
        assert a1.plan_cache == "full"
        s2 = svc.session("p2", prob, **cfg)
        s2.seed(a1.raw, entity_ids=prob.n_entities)
        a2 = s2.step(prob)
        assert a2.warm_fraction == 1.0

    def test_seed_restores_domain_state(self):
        svc = PopService()
        rng = np.random.default_rng(0)
        inst = BalanceInstance(load=rng.uniform(1, 5, 30), n_targets=6,
                               ids=np.arange(30))
        s1 = svc.session("b1", inst, solve=SolveConfig(k=2),
                         exec=ExecConfig(solver_kw=dict(max_iters=3_000)))
        a1 = s1.step(inst)
        # a fresh session seeded with the carried state behaves warm
        s2 = svc.session("b2", inst, solve=SolveConfig(k=2),
                         exec=ExecConfig(solver_kw=dict(max_iters=3_000)))
        s2.seed(a1.raw)
        a2 = s2.step(inst)
        assert a2.plan_cache == "hit" and a2.warm_fraction == 1.0

    def test_service_stats_aggregate(self):
        svc = PopService()
        prob = _traffic()
        sess = svc.session("t", prob, solve=SolveConfig(k=2),
                           exec=ExecConfig(solver_kw=KW))
        sess.step(prob)
        sess.step(prob)
        st = svc.stats()
        assert st["steps"] == 2 and st["n_sessions"] == 1
        assert st["plan_hit_rate"] == 0.5
        assert st["warm_fraction_mean"] == 1.0
