"""Serving engine behaviour: greedy decode determinism, prefill-vs-decode
consistency, cache donation shapes, POP balancer integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import forward_train, init_cache, init_params
from repro.serve.engine import ServeConfig, make_serve_step, prefill


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama3_8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_serve_step_greedy_matches_argmax(small_model):
    cfg, params = small_model
    scfg = ServeConfig(batch=2, max_seq=32)
    step = jax.jit(make_serve_step(cfg, scfg))
    cache = init_cache(cfg, 2, 32)
    tok = jnp.array([[1], [2]], jnp.int32)
    nxt, cache2 = step(params, cache, tok)
    # reference: training forward on the single token
    logits = forward_train(params, cfg, tok, compute_dtype=jnp.bfloat16)
    ref = jnp.argmax(logits[:, -1, :], axis=-1)
    np.testing.assert_array_equal(np.asarray(nxt[:, 0]), np.asarray(ref))
    assert int(cache2["pos"]) == 1


def test_decode_deterministic(small_model):
    cfg, params = small_model
    scfg = ServeConfig(batch=1, max_seq=16)
    step = jax.jit(make_serve_step(cfg, scfg))

    def rollout():
        cache = init_cache(cfg, 1, 16)
        tok = jnp.array([[3]], jnp.int32)
        out = []
        for _ in range(8):
            tok, cache = step(params, cache, tok)
            out.append(int(tok[0, 0]))
        return out

    assert rollout() == rollout()


def test_prefill_then_decode_consistent(small_model):
    """prefill(tokens) + decode(next) == decoding everything step-by-step."""
    cfg, params = small_model
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 6)), jnp.int32)

    cache_a = prefill(params, cfg, toks, init_cache(cfg, 1, 16),
                      compute_dtype=jnp.float32)

    cache_b = init_cache(cfg, 1, 16)
    from repro.models import forward_decode
    for t in range(6):
        _, cache_b = forward_decode(params, cfg, toks[:, t: t + 1], cache_b,
                                    compute_dtype=jnp.float32)

    for a, b in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4)
