"""Known-bad fixture (lives under kernels/): f64 creep in a kernel ref."""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)      # BAD: repo-wide f64


def reference(A, x):
    acc = jnp.zeros(A.shape[0], dtype=jnp.float64)     # BAD: f64 accum
    return acc + A.astype("float64") @ x.astype(np.float64)   # BAD x2
