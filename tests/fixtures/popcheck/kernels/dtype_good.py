"""Good twin: f32 end to end."""
import jax.numpy as jnp
import numpy as np


def reference(A, x):
    acc = jnp.zeros(A.shape[0], dtype=jnp.float32)
    return acc + A.astype("float32") @ x.astype(np.float32)
