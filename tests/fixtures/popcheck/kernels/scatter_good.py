"""Good twin: gather + one-hot fold (the structured-kernel design)."""
import jax
import jax.numpy as jnp


def fold(val, idx, v, n_out):
    out = jnp.sum(val * jnp.take(v, idx, axis=0), axis=0)
    onehot = idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (idx.shape[0], n_out), 1)
    return out + jnp.sum(val[:, None] * onehot.astype(val.dtype), axis=0)
