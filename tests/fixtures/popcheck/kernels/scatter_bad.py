"""Known-bad fixture (lives under kernels/): scatter in a kernel module."""
import jax
import jax.numpy as jnp


def fold(out, wide, ids):
    out = out.at[ids].add(wide)                      # BAD: scatter-add
    seg = jax.ops.segment_sum(wide, ids, out.shape[0])   # BAD: segment_sum
    return out + seg
