"""Known-bad fixture: internal code going through the compat doors."""
from repro.core import pop
from repro.core.pop import pop_solve
from repro.sched.gavel_service import GavelScheduler


def run(prob, wl):
    alloc, res, t, _ = pop.solve_full(prob, solver_kw={})      # BAD
    r = pop_solve(prob, 4, strategy="stratified")              # BAD
    sched = GavelScheduler(wl)                                 # BAD
    return alloc, r, sched
