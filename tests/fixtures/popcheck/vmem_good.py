"""Good twin: tiled blocks, comfortably VMEM-resident."""
from jax.experimental import pallas as pl

BLOCK = 256


def launch(kernel, a, out_shape):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0)),
        out_shape=out_shape,
    )(a)
