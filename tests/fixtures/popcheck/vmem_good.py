"""Good twin: tiled blocks, comfortably VMEM-resident."""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 256


def launch(kernel, a, out_shape):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0)),
        out_shape=out_shape,
    )(a)


def launch_blocked(kernel, a, out_shape, block=min(BLOCK * 2, 4096)):
    # shrink-to-extent tiles: block resolves to 512 -> (1, 512, 512)
    # blocks (1 MiB each) + a 1 MiB f32 scratch, well inside the budget
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, block, block), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, block, block), lambda i: (i, 0, 0)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
    )(a)
