"""Fixture: known-bad patterns silenced by the suppression syntax — every
finding here must be suppressed (tests assert this file scans clean)."""
import numpy as np


# popcheck: hot
def run_hot(x):
    # measured once at the boundary  # popcheck: disable=host-sync-in-hot-path
    gap = float(np.asarray(x).sum())
    # popcheck: disable=host-sync-in-hot-path
    tail = x.sum().item()
    return gap, tail
