"""Bad: tuning profiles read without the version/digest gate.

``load_profile`` parses but does not validate; skipping ``check_profile``
means a stale-format or hand-edited artifact silently tunes the service.
"""

from repro import tuning
from repro.tuning import load_profile


def read_direct(path):
    prof = load_profile(path)        # BAD: never checked
    return prof.domains


def read_via_alias(path):
    prof = tuning.load_profile(path)  # BAD: never checked
    return prof.launch_cost


# BAD: module-scope read, no check anywhere at module scope
PROFILE = load_profile("TUNING_profile.json")
