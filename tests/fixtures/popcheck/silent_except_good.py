"""Good twin for the silent-except rule: every handler is typed, records
the fault, or re-raises — nothing is swallowed silently."""


def typed_pass(step):
    # a TYPED exception may be deliberately ignored — the handler states
    # exactly what it tolerates
    try:
        return step()
    except ValueError:
        pass


def broad_recording(step, faults):
    # broad catch is fine when the fault is recorded
    try:
        return step()
    except Exception as e:
        faults.append(f"step-error:{type(e).__name__}")
        return None


def broad_reraise(step):
    try:
        return step()
    except Exception:
        raise
