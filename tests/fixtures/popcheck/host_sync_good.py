"""Good twin: same structure, everything stays on device; readbacks only
in the (cold) caller, which is not reachable from the hot root."""
import jax
import jax.numpy as jnp
import numpy as np


def _inner_step(x):
    gap = jnp.sum(x)
    mask = jnp.where(x > 0, x + 1.0, x)   # data-dependent via where
    return gap, mask


# popcheck: hot
def run_hot(x):
    return _inner_step(jnp.asarray(x))


def cold_report(x):
    # not reachable from run_hot: boundary readbacks are the point here
    gap, mask = run_hot(x)
    jax.block_until_ready(mask)
    return float(np.asarray(gap))
