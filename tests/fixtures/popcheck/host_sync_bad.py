"""Known-bad fixture: host syncs reachable from a hot root."""
import jax
import jax.numpy as jnp
import numpy as np


def _inner_step(x):
    jnp.asarray(x)                      # fine: stays on device
    gap = float(jnp.sum(x))             # BAD: float() on a traced value
    host = np.asarray(x)                # BAD: np.asarray readback
    x.block_until_ready()               # BAD: sync in the hot loop
    if jnp.any(x > 0):                  # BAD: Python branch on traced value
        host = host + 1
    return gap, host


# popcheck: hot
def run_hot(x):
    val = _inner_step(x)
    tail = x.sum().item()               # BAD: .item() readback
    got = jax.device_get(x)             # BAD: explicit device_get
    return val, tail, got
