"""Known-bad fixture: BlockSpec tiles that cannot fit VMEM."""
from jax.experimental import pallas as pl

BLOCK = 4096


def launch(kernel, a, out_shape):
    # 2 x (1, 4096, 4096) f32 blocks = 128 MiB resident >> ~16 MiB VMEM
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0)),
        out_shape=out_shape,
    )(a)
