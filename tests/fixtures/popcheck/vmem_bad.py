"""Known-bad fixture: BlockSpec tiles that cannot fit VMEM."""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 4096


def launch(kernel, a, out_shape):
    # 2 x (1, 4096, 4096) f32 blocks = 128 MiB resident >> ~16 MiB VMEM
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, BLOCK, BLOCK), lambda i: (i, 0, 0)),
        out_shape=out_shape,
    )(a)


def launch_blocked(kernel, a, out_shape, block=max(BLOCK, 2048)):
    # the M-blocked pattern: tile dims behind min/max + arithmetic, plus
    # a VMEM scratch accumulator.  block resolves to 4096, the specs to
    # (1, 4096, 8192) = 128 MiB each, the scratch adds another 128 MiB.
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, block, BLOCK * 2), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, block, BLOCK * 2), lambda i: (i, 0, 0)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block, BLOCK * 2), jnp.float32)],
    )(a)
