"""Good twin: one fill style each, as the built-in domains do it."""
from repro.domains.base import DomainSpec


def _step(inst, solve, exec_cfg, warm):
    return None


def _problem(inst):
    return None


def _hook(*a):
    return None


VIA_PROBLEM = DomainSpec(
    name="via_problem",
    problem=_problem,
    round=_hook,            # shared hooks are fine with problem=
    evaluate=_hook,
)

VIA_OVERRIDE = DomainSpec(
    name="via_override",
    step_override=_step,
    round=_hook,            # round/evaluate run on the override's output
    evaluate=_hook,
)

DECLARATIVE = DomainSpec(
    name="declarative",
    n_entities=len,
    entity_attrs=_hook,
    build_sub=_hook,
    K_mv=_hook,
    KT_mv=_hook,
    extract=_hook,
    sub_layout=_hook,
)
