"""Good twin: dict inputs frozen to item tuples in __post_init__ (the
SolveConfig/ExecConfig pattern), eq and hash defined together."""
import dataclasses
from typing import Union


@dataclasses.dataclass(frozen=True)
class FrozenConfig:
    solver_kw: Union[dict, tuple] = ()

    def __post_init__(self):
        if isinstance(self.solver_kw, dict):
            object.__setattr__(self, "solver_kw",
                               tuple(sorted(self.solver_kw.items())))


class EqAndHash:
    def __eq__(self, other):
        return isinstance(other, EqAndHash)

    def __hash__(self):
        return hash(type(self))
