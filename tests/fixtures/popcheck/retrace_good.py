"""Good twin: the memoized-builder pattern."""
import functools

import jax


@functools.lru_cache(maxsize=8)
def _cached_builder(key, fn):
    return key, fn


@functools.lru_cache(maxsize=8)
def _runner(solver):
    # jit inside an lru_cached builder: built once per solver identity
    return jax.jit(lambda c: jax.lax.map(solver, c))


def build_once(named_fn):
    return _cached_builder("k", named_fn)


def solve_cached(solver, chunked):
    return _runner(solver)(chunked)
