"""Known-bad fixture for the silent-except rule: three swallowed faults."""


def bare_handler(step):
    try:
        return step()
    except:                     # noqa: E722  -- finding 1: bare except
        pass


def broad_pass(step):
    try:
        return step()
    except Exception:           # finding 2: broad + pass-only body
        pass


def broad_ellipsis(step):
    try:
        return step()
    except BaseException:       # finding 3: broad + ellipsis body
        ...
