"""Known-bad fixture: cache-key classes that cannot actually hash."""
import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LeakyConfig:
    # BAD: dict/ndarray fields on a frozen dataclass, never re-frozen —
    # hash(LeakyConfig(...)) raises and every keyed cache breaks
    solver_kw: dict = dataclasses.field(default_factory=dict)
    weights: np.ndarray = None


class EqOnly:
    # BAD: __eq__ without __hash__ -> Python sets __hash__ = None
    def __eq__(self, other):
        return isinstance(other, EqOnly)
