"""Known-bad fixture: fresh objects defeating the jit/lru caches."""
import functools

import jax


@functools.lru_cache(maxsize=8)
def _cached_builder(key, fn):
    return key, fn


def build_each_call(data):
    # BAD: lambda arg to an lru_cached function — cache miss every call
    return _cached_builder("k", lambda x: x + 1)


def solve_each_call(solver, chunked):
    # BAD: jit of a fresh lambda invoked in place — recompiles per call
    return jax.jit(lambda c: jax.lax.map(solver, c))(chunked)


def wrap_each_call(make, batch):
    # BAD: locally-built callable jitted then invoked in the same function
    fn = jax.jit(make())
    return fn(batch)
