"""Good twin: aligned (or scalar) blocks."""
from jax.experimental import pallas as pl

VEC = pl.BlockSpec((1, 128), lambda i: (i, 0))
MAT = pl.BlockSpec((16, 256), lambda i: (i, 0))
SCALAR = pl.BlockSpec((1, 1), lambda i: (i, 0))
