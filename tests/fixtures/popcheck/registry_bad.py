"""Known-bad fixture: DomainSpec fill-style contract violations."""
from repro.domains.base import DomainSpec


def _step(inst, solve, exec_cfg, warm):
    return None


def _problem(inst):
    return None


def _build(inst, idx_row, frac, scale):
    return None


# BAD: no problem=, no step_override=, declarative hooks incomplete
INCOMPLETE = DomainSpec(
    name="incomplete",
    n_entities=len,
    build_sub=_build,
)

# BAD: step_override plus pipeline hooks the override silently ignores
OVERRIDE_MIX = DomainSpec(
    name="override_mix",
    step_override=_step,
    problem=_problem,
    K_mv=_build,
)

# BAD: problem factory mixed with declarative builder hooks
PROBLEM_MIX = DomainSpec(
    name="problem_mix",
    problem=_problem,
    build_sub=_build,
    extract=_build,
)
