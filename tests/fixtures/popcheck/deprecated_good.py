"""Good twin: the one public surface — solve_instance / solve_full_ex /
sessions; problem METHODS named like the doors are the problem's own API
and are fine."""
from repro.core import ExecConfig, SolveConfig, pop
from repro.service import PopService


def run(prob, lb_prob, inst):
    full = pop.solve_full_ex(prob, exec_cfg=ExecConfig())
    r = pop.solve_instance(prob, SolveConfig(k=4), ExecConfig())
    sess = PopService().session("tenant", domain="gavel")
    alloc = sess.step(inst)
    # method calls, not module doors: LoadBalanceProblem's own surface
    lb = lb_prob.pop_solve(4, solver_kw={})
    lb_full = lb_prob.solve_full(solver_kw={})
    return full, r, alloc, lb, lb_full
