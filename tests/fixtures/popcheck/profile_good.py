"""Good twin: every TuningProfile read passes through ``check_profile``
in the same scope before the curves are trusted."""

from repro import tuning
from repro.tuning import check_profile, load_profile


def read_direct(path):
    # the idiomatic sealed form (check_profile returns the profile)
    return check_profile(load_profile(path))


def read_via_alias(path):
    prof = tuning.load_profile(path)
    tuning.check_profile(prof, platform="cpu")
    return prof.launch_cost


def unrelated_method(store):
    # a load_profile METHOD on some other object is not the tuning door
    return store.load_profile("latest")


PROFILE = check_profile(load_profile("TUNING_profile.json"))
