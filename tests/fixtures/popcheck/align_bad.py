"""Known-bad fixture: block dims off the f32 (8, 128) tiling grid."""
from jax.experimental import pallas as pl

# last dim 100: neither 1 nor a multiple of 128
VEC = pl.BlockSpec((1, 100), lambda i: (i, 0))
# second-to-last dim 12: neither 1 nor a multiple of 8
MAT = pl.BlockSpec((12, 128), lambda i: (i, 0))
