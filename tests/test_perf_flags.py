"""§Perf optimization flags must be NUMERICALLY TRANSPARENT: sp_residual /
cache_seq_on_model change shardings and collective schedules, never math.

Runs in a subprocess with 8 forced host devices so the flags act on a real
(data=2, model=4) mesh (the main pytest process keeps 1 device)."""

import subprocess
import sys
import textwrap

from _subproc import repro_env

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_reduced
    from repro.models import transformer as tf
    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train import optimizer as opt_mod
    from repro.serve.engine import ServeConfig, make_serve_step
    from repro.models import init_cache, init_params

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # --- train: sp_residual transparency --------------------------------
    cfg = get_reduced("gemma3_4b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_mod.init_state(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)}
    losses = {}
    for flag in (False, True):
        tcfg = TrainConfig(n_microbatches=1, sp_residual=flag,
                           compute_dtype="float32")
        with mesh:
            step = jax.jit(make_train_step(cfg, tcfg, mesh))
            _, _, m = step(params, opt, batch)
        losses[flag] = float(m["loss"])
    assert abs(losses[True] - losses[False]) < 1e-4, losses
    print("sp_residual transparent:", losses)

    # --- decode: cache_seq_on_model transparency -------------------------
    cfg2 = get_reduced("llama3_8b")
    params2 = init_params(jax.random.PRNGKey(1), cfg2)
    tok = jnp.asarray(rng.integers(0, cfg2.vocab, (2, 1)), jnp.int32)
    outs = {}
    for flag in (False, True):
        scfg = ServeConfig(batch=2, max_seq=32, compute_dtype="float32",
                           cache_seq_on_model=flag)
        cache = init_cache(cfg2, 2, 32)
        with mesh:
            step = jax.jit(make_serve_step(cfg2, scfg, mesh))
            nxt, cache = step(params2, cache, tok)
            nxt2, _ = step(params2, cache, nxt)
        outs[flag] = (np.asarray(nxt), np.asarray(nxt2))
    np.testing.assert_array_equal(outs[False][0], outs[True][0])
    np.testing.assert_array_equal(outs[False][1], outs[True][1])
    print("cache_seq_on_model transparent")
""")


def test_perf_flags_numerically_transparent():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600, env=repro_env())
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "sp_residual transparent" in r.stdout
    assert "cache_seq_on_model transparent" in r.stdout
