"""Shared helper for tests that spawn subprocesses needing ``import repro``.

pytest may have found ``repro`` through a sys.path entry that was never
exported (e.g. conftest/rootdir injection), so child processes must be
handed an explicit PYTHONPATH derived from wherever THIS process imported
it — covering both a regular package (``__file__``) and the namespace
package the src/ layout actually produces (``__file__`` is None).
"""

import os

import repro


def repro_env() -> dict:
    pkg_dir = (os.path.dirname(os.path.abspath(repro.__file__))
               if getattr(repro, "__file__", None)
               else next(iter(repro.__path__)))
    src_dir = os.path.dirname(pkg_dir)
    env = dict(os.environ)
    existing = env.get("PYTHONPATH")
    # no trailing separator when PYTHONPATH is unset: an empty entry would
    # put the child's cwd on sys.path
    env["PYTHONPATH"] = (src_dir + os.pathsep + existing if existing
                         else src_dir)
    return env
