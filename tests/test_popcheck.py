"""popcheck static-analysis suite: every rule is pinned by a known-bad
fixture (fires) and a good twin (silent), plus suppression syntax,
baseline round-trips, api-drift diffing, and the repo-clean gate that
`make lint-pop` enforces in CI."""

from pathlib import Path

import pytest

from repro.analysis import RULES, run_popcheck
from repro.analysis.core import (Finding, apply_baseline, load_baseline,
                                 write_baseline)
from repro.analysis.surface import diff_surface

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "popcheck"

# (rule, bad fixture, good twin, findings expected on the bad file)
CASES = [
    ("host-sync-in-hot-path", "host_sync_bad.py", "host_sync_good.py", 6),
    ("retrace-hazard", "retrace_bad.py", "retrace_good.py", 3),
    ("pallas-vmem-budget", "vmem_bad.py", "vmem_good.py", 2),
    ("pallas-block-align", "align_bad.py", "align_good.py", 2),
    ("pallas-no-scatter", "kernels/scatter_bad.py",
     "kernels/scatter_good.py", 2),
    ("deprecated-door", "deprecated_bad.py", "deprecated_good.py", 3),
    ("dtype-promotion", "kernels/dtype_bad.py", "kernels/dtype_good.py", 4),
    ("registry-contract", "registry_bad.py", "registry_good.py", 3),
    ("config-hashability", "confighash_bad.py", "confighash_good.py", 3),
    ("silent-except", "silent_except_bad.py", "silent_except_good.py", 3),
    ("profile-staleness", "profile_bad.py", "profile_good.py", 3),
]


def _scan(rel, rule):
    return run_popcheck([FIXTURES / rel], rules=[rule])


class TestRuleFixtures:
    @pytest.mark.parametrize("rule,bad,good,n_bad",
                             CASES, ids=[c[0] for c in CASES])
    def test_fires_on_bad_silent_on_good(self, rule, bad, good, n_bad):
        bad_findings = _scan(bad, rule)
        assert len(bad_findings) == n_bad, \
            [f.render() for f in bad_findings]
        assert all(f.rule == rule for f in bad_findings)
        assert all(f.line > 0 and f.message for f in bad_findings)
        assert _scan(good, rule) == []

    def test_every_registered_rule_is_pinned(self):
        # ISSUE acceptance: >= 8 rules, each pinned by a bad fixture.
        # api-drift is pinned separately below (it diffs the live import
        # surface, not a file fixture).
        assert len(RULES) >= 8
        pinned = {c[0] for c in CASES} | {"api-drift"}
        assert pinned == set(RULES)

    def test_rules_are_cross_silent(self):
        # a bad fixture for rule A must not trip unrelated rule B —
        # keeps findings attributable and fixtures minimal
        for rule, bad, _, _ in CASES:
            others = sorted(set(RULES) - {rule, "api-drift"})
            stray = run_popcheck([FIXTURES / bad], rules=others)
            assert stray == [], [f.render() for f in stray]

    def test_unknown_rule_rejected(self):
        with pytest.raises(ValueError, match="unknown popcheck rule"):
            run_popcheck([FIXTURES], rules=["not-a-rule"])


class TestSuppression:
    def test_suppressed_file_scans_clean(self):
        # same patterns as host_sync_bad, silenced inline and line-above
        assert run_popcheck([FIXTURES / "suppressed.py"]) == []

    def test_suppression_is_rule_scoped(self):
        # the disable comments name host-sync-in-hot-path only; the
        # same file under a different rule would still report (here the
        # file is clean for other rules, so run the bad twin to prove
        # an unnamed rule is NOT covered by a foreign disable)
        findings = _scan("host_sync_bad.py", "host-sync-in-hot-path")
        assert findings  # no disables in the bad twin


class TestBaseline:
    def test_roundtrip_swallows_known_findings(self, tmp_path):
        findings = _scan("host_sync_bad.py", "host-sync-in-hot-path")
        assert findings
        path = tmp_path / "baseline.json"
        write_baseline(findings, path)
        baseline = load_baseline(path)
        assert run_popcheck([FIXTURES / "host_sync_bad.py"],
                            rules=["host-sync-in-hot-path"],
                            baseline=baseline) == []

    def test_baseline_is_count_budgeted(self):
        f = Finding("r", "p.py", 3, "msg")
        twice = [f, Finding("r", "p.py", 9, "msg")]
        # budget of 1 absorbs one occurrence, the second stays fresh
        assert apply_baseline(twice, {f.fingerprint(): 1}) == [twice[1]]

    def test_missing_baseline_loads_empty(self, tmp_path):
        assert load_baseline(tmp_path / "nope.json") == {}


class TestApiDrift:
    def test_clean_against_committed_snapshot(self):
        assert diff_surface(REPO_ROOT) == []

    def test_fires_on_stale_snapshot(self, tmp_path):
        snap = REPO_ROOT / "docs" / "api_surface.txt"
        stale = tmp_path / "api_surface.txt"
        stale.write_text(snap.read_text() +
                         "repro.bogus.vanished_function(x)\n")
        findings = diff_surface(REPO_ROOT, snapshot_path=stale)
        assert len(findings) == 1
        assert findings[0].rule == "api-drift"
        assert "vanished_function" in findings[0].message


class TestRepoClean:
    def test_tree_scans_clean_modulo_baseline(self):
        # the `make lint-pop` gate: today's src/examples/benchmarks carry
        # zero unsuppressed findings beyond the committed baseline
        baseline = load_baseline(REPO_ROOT / "popcheck_baseline.json")
        findings = run_popcheck(
            [REPO_ROOT / "src" / "repro", REPO_ROOT / "examples",
             REPO_ROOT / "benchmarks"],
            baseline=baseline, repo_root=REPO_ROOT)
        assert findings == [], "\n".join(f.render() for f in findings)
