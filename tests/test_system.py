"""End-to-end system behaviour: the paper's technique as a first-class
framework feature — POP-Gavel scheduler rounds, POP expert placement,
POP serving balancer, training-with-restart — all through public APIs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS
from repro.models import init_params
from repro.models.moe import plan_expert_placement
from repro.sched import GavelScheduler, JobSpec, SchedulerConfig
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainConfig, make_train_step
from repro.data import TokenPipeline
from repro.checkpoint import Checkpointer


def test_scheduler_round_fair_and_fast():
    sched = GavelScheduler(SchedulerConfig(
        num_workers=(64, 64, 64), pop_k=4,
        solver_kw=dict(max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)))
    rng = np.random.default_rng(0)
    for i in range(96):
        sched.submit(JobSpec(job_id=f"j{i}", arch=ARCH_IDS[i % 10],
                             priority=1.0,
                             throughputs=np.abs(rng.normal([1, .6, .8], .2))
                             + 0.05))
    alloc = sched.allocate()
    rep = sched.fairness_report()
    assert rep["n_jobs"] == 96
    assert rep["min_norm_throughput"] > 0.1      # nobody starves
    assert len(alloc) == 96
    # removing jobs shrinks the next round
    for i in range(48):
        sched.remove(f"j{i}")
    sched.allocate()
    assert sched.fairness_report()["n_jobs"] == 48


def test_expert_placement_balances_load():
    """MoE expert->device placement via the paper's LB MILP."""
    rng = np.random.default_rng(0)
    load = rng.zipf(1.5, 60).astype(np.float64)
    place = plan_expert_placement(load, n_devices=8, k=2)
    assert place.shape == (60,)
    per_dev = np.zeros(8)
    np.add.at(per_dev, place, load)
    # balanced well below the trivial worst case (everything on one device)
    assert per_dev.max() < 0.45 * load.sum()


def test_train_checkpoint_restart_bitexact(tmp_path):
    """Restart from checkpoint reproduces the exact same next step."""
    from repro.configs import get_reduced
    cfg = get_reduced("llama3_8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_mod.init_state(params)
    tcfg = TrainConfig(n_microbatches=1, adamw=opt_mod.AdamWConfig(
        peak_lr=1e-3, warmup_steps=2, total_steps=10))
    step = jax.jit(make_train_step(cfg, tcfg, mesh=None))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=2, seq=32, seed=3)
    it = iter(pipe)

    ck = Checkpointer(str(tmp_path))
    b1 = {k: jnp.asarray(v) for k, v in next(it).items()}
    params, opt, _ = step(params, opt, b1)
    ck.save(1, {"params": params, "opt": opt},
            extras={"pipe": pipe.state()})

    b2 = {k: jnp.asarray(v) for k, v in next(it).items()}
    params_a, opt_a, m_a = step(params, opt, b2)

    restored, extras = ck.restore(1, {"params": params, "opt": opt})
    pipe2 = TokenPipeline(vocab=cfg.vocab, batch=2, seq=32, seed=3)
    pipe2.restore(extras["pipe"])
    b2r = {k: jnp.asarray(v) for k, v in next(iter(pipe2)).items()}
    np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                  np.asarray(b2r["tokens"]))
    params_b, opt_b, m_b = step(restored["params"], restored["opt"], b2r)
    assert float(m_a["loss"]) == pytest.approx(float(m_b["loss"]), abs=1e-6)
    for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pop_shard_map_backend_matches_vmap():
    """The mesh-distributed map step returns the same sub-solutions as the
    single-device vmap backend (POP sub-problem independence)."""
    from repro.core import pop
    from repro.problems.cluster_scheduling import (GavelProblem,
                                                   make_cluster_workload)
    wl = make_cluster_workload(32, num_workers=(8, 8, 8), seed=5)
    prob = GavelProblem(wl, space_sharing=False)
    kw = dict(max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)
    r_vmap = pop.pop_solve(prob, 2, strategy="stratified", backend="vmap",
                           solver_kw=kw)
    r_smap = pop.pop_solve(prob, 2, strategy="stratified",
                           backend="shard_map", solver_kw=kw)
    np.testing.assert_allclose(r_vmap.alloc, r_smap.alloc, rtol=5e-3,
                               atol=5e-3)
