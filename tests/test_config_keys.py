"""Cache-key contract for SolveConfig/ExecConfig: hash/eq consistency is
asserted at CONSTRUCTION (``config._check_cache_key``), and the keys
survive dataclass evolution — a subclass adding a field still
distinguishes configs in an lru_cache, so growing the config never
silently aliases two different solver setups onto one compiled entry."""

import dataclasses
import functools

import pytest

from repro.core import ExecConfig, SolveConfig


@dataclasses.dataclass(frozen=True)
class _GrownExec(ExecConfig):
    # tomorrow's field, added after caches started keying on ExecConfig
    pipeline_depth: int = 1


@dataclasses.dataclass(frozen=True)
class _LeakyExec(ExecConfig):
    # a field that defeats freezing — must fail at construction
    gadgets: list = dataclasses.field(default_factory=list)


class TestConstructionCheck:
    def test_unhashable_field_fails_at_construction(self):
        with pytest.raises(TypeError, match="must stay hashable"):
            _LeakyExec(backend="vmap")

    def test_error_names_the_class(self):
        with pytest.raises(TypeError, match="_LeakyExec"):
            _LeakyExec()

    def test_dict_fields_are_frozen_not_rejected(self):
        cfg = ExecConfig(solver_kw={"max_iters": 50},
                         backend_opts={"chunk": 4})
        assert isinstance(cfg.solver_kw, tuple)
        assert isinstance(cfg.backend_opts, tuple)
        assert hash(cfg) == hash(ExecConfig(solver_kw={"max_iters": 50},
                                            backend_opts={"chunk": 4}))

    def test_replace_roundtrip_is_identity_key(self):
        for cfg in (SolveConfig(k=4, strategy="stratified"),
                    ExecConfig(solver_kw={"max_iters": 50})):
            twin = dataclasses.replace(cfg)
            assert twin == cfg and hash(twin) == hash(cfg)


class TestKeysSurviveFieldAdditions:
    def test_new_field_distinguishes_configs(self):
        a = _GrownExec(solver_kw={"max_iters": 50}, pipeline_depth=1)
        b = _GrownExec(solver_kw={"max_iters": 50}, pipeline_depth=2)
        assert a != b
        assert hash(a) != hash(b)   # dataclass hash covers ALL fields

    def test_lru_cache_keyed_on_config_sees_new_field(self):
        calls = []

        @functools.lru_cache(maxsize=8)
        def build(cfg):
            calls.append(cfg)
            return object()

        a = _GrownExec(pipeline_depth=1)
        b = _GrownExec(pipeline_depth=2)
        s1 = build(a)
        s2 = build(b)
        assert s1 is not s2 and len(calls) == 2
        # equal reconstruction hits the cache — no spurious recompiles
        assert build(dataclasses.replace(a)) is s1
        assert len(calls) == 2

    def test_subclass_inherits_validation(self):
        with pytest.raises(ValueError, match="unknown backend"):
            _GrownExec(backend="warp_drive")
        with pytest.raises(ValueError, match="solver_kw"):
            _GrownExec(solver_kw={"max_itres": 5})

    def test_base_and_subclass_never_alias(self):
        base = ExecConfig()
        grown = _GrownExec()
        assert base != grown    # dataclass eq requires same class
