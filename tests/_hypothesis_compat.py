"""Optional-hypothesis shim.

Test modules do ``from _hypothesis_compat import given, settings, st``
instead of importing ``hypothesis`` directly.  When hypothesis is
installed, these are the real objects; when it is missing, ``@given``
turns the test into a clean skip and the strategy/settings surfaces are
inert stand-ins, so module collection — and every non-property test in
the module — still works.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: strategy constructors are
        called at decoration time, so they must exist and accept anything."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # a fresh zero-arg function: pytest must not see the wrapped
            # test's hypothesis parameters and demand fixtures for them
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
