"""Step-engine contract tests (``core/pdhg.py`` + ``core/backends.py``).

The fused dense engine must be numerically interchangeable with the
generic matvec engine — same algorithm, different execution.  Equivalence
is pinned on FIXED iteration budgets (tolerances set to 0 so no lane
terminates early), which compares trajectories rather than "two different
converged points", plus warm-start behaviour for the online re-solve path.

(The full engine x backend x domain matrix — including the third,
``fused_structured`` engine and the in-loop-KKT bit-level gate — lives in
``tests/test_engine_conformance.py`` / ``make test-conformance``; this
module keeps the dense-engine and warm-start specifics.)
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import backends as backends_mod
from repro.core import pdhg, pop
from repro.core.pdhg import BIG, OperatorLP
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload

# fixed-budget solver settings: tol 0 => every lane runs max_iters exactly
FIXED_KW = dict(max_iters=400, check_every=40, tol_primal=0.0, tol_gap=0.0)


def _dense_stack(k=3, n=33, mi=17, seed=0):
    """k raw (UNPADDED) dense LPs stacked: 17x33 is deliberately not a
    multiple of any kernel block size, so the fused path exercises the
    pad-and-slice logic of ``kernels/ops.py`` end to end."""
    subs = []
    for i in range(k):
        rng = np.random.default_rng(seed + i)
        c = rng.normal(size=n)
        G = rng.normal(size=(mi, n))
        h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)
        subs.append(OperatorLP(
            c=jnp.asarray(c, jnp.float32), q=jnp.asarray(h, jnp.float32),
            l=jnp.zeros(n, jnp.float32), u=jnp.ones(n, jnp.float32),
            ineq_mask=jnp.ones(mi, bool),
            data=(jnp.asarray(G, jnp.float32),)))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *subs)


@pytest.fixture(scope="module")
def dense_ops6():
    return _dense_stack(k=6)


@pytest.fixture(scope="module")
def matvec_ref(dense_ops6):
    return backends_mod.solve_map(dense_ops6, pdhg.dense_K_mv, pdhg.dense_KT_mv,
                                  FIXED_KW, backend="vmap", engine="matvec")


@pytest.mark.parametrize("backend", sorted(backends_mod.MAP_BACKENDS))
def test_fused_matches_matvec_every_backend(backend, dense_ops6, matvec_ref):
    """Acceptance: fused == matvec to 1e-5 on batched dense solves through
    ALL five map backends (same fixed budget => same trajectory)."""
    opts = {"chunk": 4} if backend == "chunked_vmap" else {}
    r = backends_mod.solve_map(dense_ops6, pdhg.dense_K_mv, pdhg.dense_KT_mv,
                               FIXED_KW, backend=backend, engine="fused",
                               **opts)
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(matvec_ref.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r.y), np.asarray(matvec_ref.y),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r.iterations),
                                  np.asarray(matvec_ref.iterations))


def test_fused_interpret_mode_padding(matvec_ref):
    """The REAL Pallas kernel bodies (interpreter on CPU, compiled on TPU)
    through a full solve on non-block-multiple shapes: exercises M/N
    padding inside every inner-loop step.  Short budget — interpret mode
    is slow by design."""
    ops = _dense_stack(k=3)
    kw = dict(FIXED_KW, max_iters=80)
    kernel = "pallas" if jax.default_backend() == "tpu" else "interpret"
    eng = pdhg.fused_dense_engine(kernel_backend=kernel,
                                  block_m=64, block_n=64)
    ri = pdhg.solve_stacked(ops, engine=eng, **kw)
    rx = pdhg.solve_stacked(ops, engine="matvec", **kw)
    np.testing.assert_allclose(np.asarray(ri.x), np.asarray(rx.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ri.y), np.asarray(rx.y),
                               rtol=1e-5, atol=1e-5)


def test_fused_with_equilibrate(dense_ops6, matvec_ref):
    """Equilibration composes with the fused engine by scaling the dense K
    (scale_data), matching the matvec engine's functional wrapping."""
    rf = pdhg.solve_stacked(dense_ops6, engine="fused", equilibrate=True,
                            **FIXED_KW)
    rm = pdhg.solve_stacked(dense_ops6, engine="matvec", equilibrate=True,
                            **FIXED_KW)
    np.testing.assert_allclose(np.asarray(rf.x), np.asarray(rm.x),
                               rtol=1e-5, atol=1e-5)


def test_engine_selection():
    ops = _dense_stack(k=2)
    assert pdhg.is_dense_ops(ops)
    # structured data => matvec, everywhere
    structured = ops._replace(data=(ops.data[0], jnp.zeros(3)))
    assert not pdhg.is_dense_ops(structured)
    assert pdhg.select_engine(structured) == "matvec"
    # dense data: fused only on TPU
    expected = "fused" if jax.default_backend() == "tpu" else "matvec"
    assert pdhg.select_engine(ops) == expected
    # custom (non-dense) matvecs disqualify fused even with dense-shaped data
    assert pdhg.select_engine(ops, K_mv=lambda d, x: d[0] @ x) == "matvec"
    with pytest.raises(ValueError, match="fused"):
        backends_mod.solve_map(structured, pdhg.dense_K_mv, pdhg.dense_KT_mv,
                               FIXED_KW, backend="vmap", engine="fused")
    with pytest.raises(ValueError, match="unknown engine"):
        backends_mod.solve_map(ops, pdhg.dense_K_mv, pdhg.dense_KT_mv,
                               FIXED_KW, backend="vmap", engine="warp")


def test_kernel_backend_dispatch():
    from repro.kernels import ops as kops
    mode = kops._resolve_mode(None)
    assert mode == ("pallas" if jax.default_backend() == "tpu" else "xla")
    assert kops._resolve_mode("interpret") == "interpret"
    with pytest.raises(ValueError, match="kernel backend"):
        kops._resolve_mode("vulkan")


# ---------------------------------------------------------------------------
# warm starts (the online re-solve path)
# ---------------------------------------------------------------------------

def test_warm_start_at_optimum_converges_immediately():
    """Re-solving the SAME problem from its own solution must terminate at
    the first KKT check — with and without equilibration (the warm iterates
    are rescaled into the equilibrated space)."""
    ops = _dense_stack(k=1)
    op = jax.tree.map(lambda a: a[0], ops)
    for eq in (False, True):
        r1 = pdhg.solve(op, equilibrate=eq, max_iters=40_000)
        assert bool(r1.converged)
        r2 = pdhg.solve(op, equilibrate=eq, max_iters=40_000,
                        warm_x=r1.x, warm_y=r1.y)
        # a handful of KKT-check chunks at most, and far below the cold run
        assert int(r2.iterations) <= 5 * 40, (eq, int(r2.iterations))
        assert int(r2.iterations) <= int(r1.iterations) / 2


def test_pop_warm_resolve_halves_iterations():
    """ISSUE acceptance: a perturbed online re-solve warm-started from the
    previous round converges in <= half the cold-start iterations (same
    partition for a like-for-like comparison) at equal quality."""
    kw = dict(max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)
    wl = make_cluster_workload(32, num_workers=(8, 8, 8), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=kw)
    assert prev.x is not None and prev.y is not None

    rng = np.random.default_rng(7)
    wl2 = dataclasses.replace(wl, T=wl.T * rng.uniform(0.99, 1.01, wl.T.shape))
    prob2 = GavelProblem(wl2, space_sharing=False)
    cold = pop.pop_solve(prob2, 4, partition_idx=prev.idx, solver_kw=kw)
    warm = pop.pop_solve(prob2, 4, warm=prev, solver_kw=kw)
    assert bool(warm.converged.all())
    assert warm.iterations.sum() <= cold.iterations.sum() / 2, (
        warm.iterations, cold.iterations)
    # same partition, near-identical allocation quality
    np.testing.assert_array_equal(warm.idx, prev.idx)
    assert abs(warm.alloc.mean() - cold.alloc.mean()) < 5e-3


def test_warm_shape_mismatch_rejected():
    ops = _dense_stack(k=3)
    with pytest.raises(ValueError, match="warm-start shapes"):
        backends_mod.solve_map(ops, pdhg.dense_K_mv, pdhg.dense_KT_mv,
                               FIXED_KW, backend="vmap",
                               warm=(jnp.zeros((2, 5)), jnp.zeros((2, 4))))


def test_pop_warm_across_k_change_remaps():
    """PR-2 raised on a k mismatch; the PopPlan layer remaps instead —
    ``pop_solve(warm=)`` is total across k changes (ISSUE 3 acceptance)."""
    kw = dict(max_iters=2_000, tol_primal=1e-4, tol_gap=1e-4)
    wl = make_cluster_workload(16, num_workers=(8, 8, 8), seed=1)
    prob = GavelProblem(wl, space_sharing=False)
    prev = pop.pop_solve(prob, 2, solver_kw=kw)
    res = pop.pop_solve(prob, 4, warm=prev, solver_kw=kw)
    assert res.idx.shape[0] == 4
    assert res.warm_stats is not None
    assert res.warm_stats["warm_fraction"] == 1.0   # every job matched


# ---------------------------------------------------------------------------
# shared Ruiz scaling helpers (BIG-sentinel handling cannot diverge)
# ---------------------------------------------------------------------------

def test_scale_operator_preserves_big_sentinels():
    n, m = 4, 3
    op = OperatorLP(
        c=jnp.ones(n), q=jnp.asarray([1.0, BIG, 2.0]),
        l=jnp.asarray([0.0, -BIG, 0.5, -BIG]),
        u=jnp.asarray([1.0, BIG, BIG, 2.0]),
        ineq_mask=jnp.ones(m, bool), data=(jnp.ones((m, n)),))
    d_r = jnp.full(m, 2.0)
    d_c = jnp.full(n, 4.0)
    s = pdhg.scale_operator(op, d_r, d_c)
    # finite bounds scale by 1/d_c, BIG sentinels pass through untouched
    np.testing.assert_allclose(np.asarray(s.l), [0.0, -BIG, 0.125, -BIG])
    np.testing.assert_allclose(np.asarray(s.u), [0.25, BIG, BIG, 0.5])
    np.testing.assert_allclose(np.asarray(s.c), 4.0 * np.ones(n))
    # q scales unconditionally (BIG rows have zero K rows => d_r stays 1
    # in the real equilibration paths)
    np.testing.assert_allclose(np.asarray(s.q), [2.0, 2.0 * BIG, 4.0])
    # round trip: unscale(scale(x)) == x
    x = jnp.arange(1.0, n + 1)
    y = jnp.arange(1.0, m + 1)
    xs, ys = pdhg.scale_warm_start(x, y, d_r, d_c)
    xr, yr = pdhg.unscale_solution(xs, ys, d_r, d_c)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x))
    np.testing.assert_allclose(np.asarray(yr), np.asarray(y))


def test_ruiz_dense_uses_shared_helper():
    """ruiz_equilibrate and the probe path must agree on bounds masking:
    equilibrated dense solve still matches the unscaled solution."""
    ops = _dense_stack(k=1, n=20, mi=12, seed=9)
    op = jax.tree.map(lambda a: a[0], ops)
    sop, d_r, d_c = pdhg.ruiz_equilibrate(op)
    r_scaled = pdhg.solve(sop, max_iters=40_000)
    x, y = pdhg.unscale_solution(r_scaled.x, r_scaled.y, d_r, d_c)
    r_plain = pdhg.solve(op, max_iters=40_000)
    assert abs(float(jnp.dot(op.c, x)) - float(r_plain.primal_obj)) < 2e-3


# ---------------------------------------------------------------------------
# end-to-end: warm-started load balancing + serving balancer ticks
# ---------------------------------------------------------------------------

def test_lb_warm_resolve():
    from repro.problems.load_balancing import (LoadBalanceProblem,
                                               make_shard_workload)
    kw = dict(max_iters=6_000, tol_primal=1e-4, tol_gap=1e-4)
    wl = make_shard_workload(48, 8, seed=2)
    prev = LoadBalanceProblem(wl).pop_solve(4, solver_kw=kw)
    rng = np.random.default_rng(5)
    wl2 = dataclasses.replace(
        wl, load=wl.load * rng.uniform(0.98, 1.02, wl.load.shape),
        placement=prev.placement)
    prob2 = LoadBalanceProblem(wl2)
    cold = prob2.pop_solve(4, solver_kw=kw, warm=prev, warm_start=False)
    warm = prob2.pop_solve(4, solver_kw=kw, warm=prev)
    assert warm.extra["iterations"] <= cold.extra["iterations"]
    assert warm.feasible == cold.feasible
