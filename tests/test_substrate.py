"""Substrate tests: checkpointing (atomic commit, restart, async), data
pipeline determinism+restore, fault-tolerance planning, gradient
compression, optimizer behaviour, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.sched.elastic import (HeartbeatMonitor, StragglerDetector,
                                 plan_remesh, scale_microbatches, redispatch,
                                 speculative_backups)
from repro.train import compression as comp
from repro.train import optimizer as opt_mod


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(3,)), jnp.float32),
                  "d": jnp.asarray(rng.integers(0, 5, (2, 2)), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t, extras={"data_cursor": 42})
    assert ck.latest() == 7
    restored, extras = ck.restore(7, jax.tree.map(jnp.zeros_like, t))
    assert extras["data_cursor"] == 42
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    for s in (1, 3, 2):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert ck.latest() == 3


def test_checkpoint_atomicity_no_tmp_visible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    # a stale tmp dir from a crashed writer must not be listed
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ck.latest() == 1


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    bad = {"a": jnp.zeros((8, 4)), "b": {"c": jnp.zeros((3,))}}  # missing d
    with pytest.raises(AssertionError):
        ck.restore(1, bad)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restorable():
    p1 = TokenPipeline(vocab=100, batch=4, seq=16, seed=9)
    it1 = iter(p1)
    batches = [next(it1) for _ in range(3)]
    cursor = p1.state()

    p2 = TokenPipeline(vocab=100, batch=4, seq=16, seed=9)
    p2.restore(cursor)
    nxt = next(iter(p2))
    ref = next(it1)
    np.testing.assert_array_equal(nxt["tokens"], ref["tokens"])
    # label = next-token shift of the same stream
    np.testing.assert_array_equal(batches[0]["tokens"][:, 1:],
                                  batches[0]["labels"][:, :-1])


# ---------------------------------------------------------------------------
# fault tolerance / elasticity
# ---------------------------------------------------------------------------

def test_heartbeat_states():
    hb = HeartbeatMonitor(timeout_s=30, suspect_s=10)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(1, now=24.0)
    st_ = hb.status(now=36.0)
    assert st_[0] == "dead" and st_[1] == "suspect"
    assert hb.alive(now=36.0) == [1]


def test_straggler_detection():
    sd = StragglerDetector(k=4.0)
    for w in range(8):
        for _ in range(16):
            sd.record(w, 1.0 + 0.01 * w)
    for _ in range(16):
        sd.record(8, 3.0)            # 3x slower
    assert sd.stragglers() == [8]


def test_plan_remesh_shrinks_data_axis():
    plan = plan_remesh(n_alive=480, model_parallel=16)
    assert plan["ok"]
    assert plan["mesh_shape"][-1] == 16
    assert plan["chips_used"] <= 480
    assert plan["chips_used"] % 16 == 0
    # too few chips for even one model group
    assert not plan_remesh(8, 16)["ok"]


def test_scale_microbatches_preserves_global_batch():
    # 256 global, 8 micro at 16-way DP -> per-dev-micro 2; shrink to 12-way
    n_new = scale_microbatches(global_batch=256, n_micro_old=8, data_old=16,
                               data_new=8)
    assert 256 % (n_new * 8) == 0


def test_redispatch_covers_all_subproblems():
    assign = {0: [0, 1], 1: [2, 3], 2: [4, 5]}
    new = redispatch(assign, dead=[1], alive=[0, 2])
    got = sorted(sum(new.values(), []))
    assert got == [0, 1, 2, 3, 4, 5]
    assert 1 not in new


def test_speculative_backups_past_deadline():
    pending = {10: 0.0, 11: 5.0}
    assert speculative_backups(pending, now=12.0, deadline_s=10.0) == [10]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(513,)) * rng.uniform(0.1, 10), jnp.float32)
    q, s = comp.quantize_int8(x)
    x2 = comp.dequantize_int8(q, s, x.shape)
    # error bounded by half a quantisation step per block
    err = np.abs(np.asarray(x - x2))
    bound = np.repeat(np.asarray(s).ravel(), comp.BLOCK)[: x.size] * 0.5 + 1e-7
    assert (err <= bound).all()


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the RUNNING SUM of dequantised grads tracks the
    running sum of true grads (the residual never grows unboundedly)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(300,)), jnp.float32)
              for _ in range(20)]
    r = jnp.zeros((300,), jnp.float32)
    sent = jnp.zeros((300,), jnp.float32)
    for g in g_true:
        q, s, r = comp.compress_with_feedback(g, r)
        sent = sent + comp.dequantize_int8(q, s, g.shape)
    total = sum(np.asarray(g) for g in g_true)
    # residual bounds the discrepancy
    np.testing.assert_allclose(np.asarray(sent + r), total, rtol=1e-4,
                               atol=1e-4)
    assert float(jnp.abs(r).max()) < 0.5     # bounded residual


def test_compressed_psum_under_shard_map():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import compat
    mesh = Mesh(np.array(devs[:1]), ("dp",))
    g = {"w": jnp.ones((64,), jnp.float32)}
    r = comp.init_residuals(g)

    def f(g, r):
        return comp.compressed_psum(g, r, "dp")

    out, r2 = jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check=False))(g, r)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-2)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = opt_mod.AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=100,
                              weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_mod.init_state(params)
    for _ in range(50):
        grads = {"w": 2.0 * params["w"]}          # d/dw ||w||^2
        params, state, _ = opt_mod.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_wd_skips_norm_scales():
    cfg = opt_mod.AdamWConfig(peak_lr=0.0, warmup_steps=0, total_steps=10,
                              weight_decay=1.0)   # lr=0: only wd could move
    params = {"w": jnp.ones((2,)), "scale": jnp.ones((2,))}
    state = opt_mod.init_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = opt_mod.apply_updates(cfg, params, grads, state)
    np.testing.assert_array_equal(np.asarray(p2["scale"]),
                                  np.asarray(params["scale"]))


def test_lr_schedule_shape():
    cfg = opt_mod.AdamWConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                              total_steps=100)
    lrs = [float(opt_mod.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_structure_matches():
    from repro.configs import get_config
    from repro.launch import shardings as sh
    import repro.launch.specs as sp
    cfg = get_config("llama3_8b")
    p_shape = sp.params_shape(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = sh.param_specs(p_shape, mesh)
    assert (jax.tree_util.tree_structure(specs)
            == jax.tree_util.tree_structure(p_shape))
    # every spec rank matches its leaf rank
    for leaf, spec in zip(jax.tree.leaves(p_shape), jax.tree.leaves(specs)):
        assert len(spec) == leaf.ndim or len(spec) <= leaf.ndim


def test_sharding_divisibility_all_archs():
    """Every spec dimension marked 'model' must divide by 16 on the
    production mesh — for ALL archs (this is the bug class the dry-run
    would otherwise hit one cell at a time)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.launch import shardings as sh
    import repro.launch.specs as sp

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        p_shape = sp.params_shape(cfg)
        specs = sh.param_specs(p_shape, FakeMesh())
        flat_p = jax.tree.leaves(p_shape)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))
        for leaf, spec in zip(flat_p, flat_s):
            for dim, ax in enumerate(spec):
                if ax == "model":
                    assert leaf.shape[dim] % 16 == 0, (arch, leaf.shape, spec)
