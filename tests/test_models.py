"""Model-level correctness: decode == teacher-forced forward (the cache
path is exactly equivalent to the parallel path), SWA masking semantics,
MoE routing invariants, SSM/xLSTM recurrence vs parallel form."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import (forward_decode, forward_train, init_cache,
                          init_params, encode)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


# decode-vs-train equivalence is THE serving correctness property: running
# the cached decode path token by token must reproduce the parallel
# (training) forward exactly (up to bf16 noise).
DECODE_EQUIV_ARCHS = ["llama3_8b", "h2o_danube3_4b", "gemma2_27b",
                      "gemma3_4b", "mixtral_8x22b", "zamba2_2_7b",
                      "xlstm_350m", "chameleon_34b"]


@pytest.mark.parametrize("arch", DECODE_EQUIV_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 24
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    ref = forward_train(params, cfg, tokens, compute_dtype=jnp.float32)
    # the cache dtype must match the compute dtype: a bf16 cache under
    # float32 decode truncates the KV history each step, which drifts the
    # logits ~1e-2 from the teacher-forced forward (MoE gating amplifies
    # the truncation into near-tolerance failures, e.g. mixtral)
    cache = init_cache(cfg, B, S, kv_dtype=jnp.float32)
    step = jax.jit(lambda tok, c: forward_decode(params, cfg, tok, c,
                                                 compute_dtype=jnp.float32))
    outs = []
    for t in range(S):
        lg, cache = step(tokens[:, t: t + 1], cache)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_swa_window_masks_old_tokens():
    """A token beyond the window must not influence attention output."""
    rng = jax.random.PRNGKey(0)
    p = attn_mod.init_attention(rng, 32, 4, 2, 8)
    B, S, W = 1, 12, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32), jnp.float32)
    y1 = attn_mod.attention_train(p, x, window=float(W), softcap=0.0,
                                  rope_theta=1e4)
    # perturb position 0 — outputs at positions >= W must be unchanged
    x2 = x.at[:, 0].add(10.0)
    y2 = attn_mod.attention_train(p, x2, window=float(W), softcap=0.0,
                                  rope_theta=1e4)
    np.testing.assert_allclose(np.asarray(y1[:, W:]), np.asarray(y2[:, W:]),
                               rtol=1e-5, atol=1e-5)
    # ...and the position inside the window IS affected
    assert float(jnp.abs(y1[:, 1] - y2[:, 1]).max()) > 1e-4


def test_causality():
    """Future tokens never leak into past positions."""
    rng = jax.random.PRNGKey(0)
    p = attn_mod.init_attention(rng, 32, 4, 2, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 32), jnp.float32)
    y1 = attn_mod.attention_train(p, x, window=100.0, softcap=0.0,
                                  rope_theta=1e4)
    x2 = x.at[:, -1].add(10.0)
    y2 = attn_mod.attention_train(p, x2, window=100.0, softcap=0.0,
                                  rope_theta=1e4)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_softcap_bounds_logit_influence():
    """With softcap, pre-softmax logits are bounded by the cap."""
    logits = jnp.linspace(-1000, 1000, 64)
    capped = attn_mod._soft_cap(logits, jnp.asarray(50.0))
    assert float(jnp.abs(capped).max()) <= 50.0 + 1e-4
    uncapped = attn_mod._soft_cap(logits, jnp.asarray(0.0))
    np.testing.assert_allclose(np.asarray(uncapped), np.asarray(logits))


def test_moe_expert_mixture_sums_to_one():
    """Top-k gate weights are renormalised; unrouted (dropped) tokens get
    zero expert output but the shared expert still applies."""
    rng = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(rng, 16, 32, n_experts=4, n_shared=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    y = moe_mod.moe(p, x, top_k=2, capacity_factor=4.0)   # no drops at cf=4
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # zero input -> zero routed output (silu(0)*0 = 0 through experts)
    y0 = moe_mod.moe(p, jnp.zeros_like(x), top_k=2)
    np.testing.assert_allclose(np.asarray(y0), 0.0, atol=1e-6)


def test_mamba2_decode_matches_train():
    """Step-by-step SSM recurrence == chunked parallel scan."""
    rng = jax.random.PRNGKey(0)
    p = ssm_mod.init_mamba2(rng, 32, d_state=8, expand=2, head_dim=8)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32), jnp.float32) * 0.5
    y_par = ssm_mod.mamba2_train(p, x, chunk=4)
    state = ssm_mod.mamba2_init_state(p, B)
    outs = []
    for t in range(S):
        y, state = ssm_mod.mamba2_decode(p, x[:, t: t + 1], state)
        outs.append(y[:, 0])
    y_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=3e-3, atol=3e-3)


def test_mlstm_decode_matches_train():
    rng = jax.random.PRNGKey(0)
    p = xlstm_mod.init_mlstm(rng, 32, n_heads=2)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32), jnp.float32) * 0.5
    y_par = xlstm_mod.mlstm_train(p, x)
    state = xlstm_mod.mlstm_init_state(p, B)
    outs = []
    for t in range(S):
        y, state = xlstm_mod.mlstm_decode(p, x[:, t: t + 1], state)
        outs.append(y[:, 0])
    y_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=3e-3, atol=3e-3)


def test_slstm_decode_matches_train():
    rng = jax.random.PRNGKey(0)
    p = xlstm_mod.init_slstm(rng, 32, n_heads=2)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32), jnp.float32) * 0.5
    y_par = xlstm_mod.slstm_train(p, x)
    state = xlstm_mod.slstm_init_state(p, B)
    outs = []
    for t in range(S):
        y, state = xlstm_mod.slstm_decode(p, x[:, t: t + 1], state)
        outs.append(y[:, 0])
    y_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=3e-3, atol=3e-3)


def test_ring_buffer_cache_wraps_correctly():
    """Decoding past the window with a ring cache == decoding with a full
    cache, for positions where only the window matters."""
    arch = "h2o_danube3_4b"
    cfg = get_reduced(arch)          # window = 32
    params = init_params(jax.random.PRNGKey(2), cfg)
    B, S = 1, 48                     # exceeds the 32-token window
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    ref = forward_train(params, cfg, tokens, compute_dtype=jnp.float32)
    cache = init_cache(cfg, B, S)    # ring len = min(S, 32) = 32
    step = jax.jit(lambda tok, c: forward_decode(params, cfg, tok, c,
                                                 compute_dtype=jnp.float32))
    outs = []
    for t in range(S):
        lg, cache = step(tokens[:, t: t + 1], cache)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_encoder_decoder_cross_attention():
    cfg = get_reduced("seamless_m4t_medium")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    enc_emb = jnp.asarray(np.random.default_rng(0).normal(0, 1, (B, 16, cfg.d_model)),
                          jnp.float32)
    memory = encode(params, cfg, enc_emb)
    assert memory.shape == (B, 16, cfg.d_model)
    # decoder output depends on the encoder memory
    tokens = jnp.zeros((B, 8), jnp.int32)
    lg1 = forward_train(params, cfg, tokens, enc_embeddings=enc_emb,
                        compute_dtype=jnp.float32)
    lg2 = forward_train(params, cfg, tokens, enc_embeddings=enc_emb * 2.0,
                        compute_dtype=jnp.float32)
    assert float(jnp.abs(lg1 - lg2).max()) > 1e-4
