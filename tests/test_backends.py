"""Execution-substrate tests: every registered map-step backend must return
the same sub-problem solutions as ``vmap`` (backends differ in scheduling,
never in math), including when k does not divide the device/chunk count
(the padding path).  Multi-device shard_map/pmap padding runs in a
subprocess with forced host devices (the main pytest process keeps 1)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from _subproc import repro_env
from repro.core import backends as backends_mod
from repro.core import compat, pop
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload

SOLVER_KW = dict(max_iters=4_000, tol_primal=1e-4, tol_gap=1e-4)


def _problem(n_jobs=30, seed=5):
    wl = make_cluster_workload(n_jobs, num_workers=(8, 8, 8), seed=seed)
    return GavelProblem(wl, space_sharing=False)


@pytest.fixture(scope="module")
def vmap_ref():
    # k=6: not a multiple of chunked_vmap's test chunk (4) — on a
    # multi-device mesh it also exercises the shard_map/pmap padding
    return pop.pop_solve(_problem(), 6, strategy="stratified",
                         backend="vmap", solver_kw=SOLVER_KW)


@pytest.mark.parametrize("backend", sorted(backends_mod.MAP_BACKENDS))
def test_backend_matches_vmap(backend, vmap_ref):
    opts = {"chunk": 4} if backend == "chunked_vmap" else {}
    r = pop.pop_solve(_problem(), 6, strategy="stratified", backend=backend,
                      solver_kw=SOLVER_KW, backend_opts=opts)
    np.testing.assert_allclose(r.alloc, vmap_ref.alloc, atol=1e-6)
    np.testing.assert_array_equal(r.iterations, vmap_ref.iterations)


def test_auto_backend_matches_vmap(vmap_ref):
    r = pop.pop_solve(_problem(), 6, strategy="stratified", backend="auto",
                      solver_kw=SOLVER_KW)
    np.testing.assert_allclose(r.alloc, vmap_ref.alloc, atol=1e-6)


def test_auto_backend_drops_foreign_opts(vmap_ref):
    """Under auto, opts are hints for whichever backend wins: chunk= must
    not crash when auto resolves to vmap.  Explicitly named backends still
    reject opts they don't take."""
    r = pop.pop_solve(_problem(), 6, strategy="stratified", backend="auto",
                      solver_kw=SOLVER_KW, backend_opts=dict(chunk=4))
    np.testing.assert_allclose(r.alloc, vmap_ref.alloc, atol=1e-6)
    with pytest.raises(TypeError):
        pop.pop_solve(_problem(), 6, backend="vmap", solver_kw=SOLVER_KW,
                      backend_opts=dict(chunk=4))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown map backend"):
        backends_mod.get_backend("warp_drive")


def test_pad_to_multiple():
    import jax.numpy as jnp
    from repro.core.pdhg import OperatorLP
    ops = OperatorLP(c=jnp.ones((6, 3)), q=jnp.ones((6, 2)),
                     l=jnp.zeros((6, 3)), u=jnp.ones((6, 3)),
                     ineq_mask=jnp.ones((6, 2), bool), data=(jnp.ones((6, 2, 3)),))
    padded, k = backends_mod.pad_to_multiple(ops, 4)
    assert k == 6
    assert backends_mod.batch_size(padded) == 8
    # padding replicates sub-problem 0
    np.testing.assert_array_equal(np.asarray(padded.c[6:]),
                                  np.asarray(ops.c[:1].repeat(2, 0)))
    # already-multiple is a no-op (same object, no copy)
    same, k2 = backends_mod.pad_to_multiple(ops, 3)
    assert same is ops and k2 == 6


def test_select_backend_heuristics():
    assert backends_mod.select_backend(4, 100, n_dev=1) == "vmap"
    assert backends_mod.select_backend(6, 100, n_dev=4) == "shard_map"
    # fewer sub-problems than devices: not worth a mesh
    assert backends_mod.select_backend(2, 100, n_dev=4) == "vmap"
    # large k or a huge stacked footprint bounds memory via chunking
    assert backends_mod.select_backend(
        backends_mod.AUTO_VMAP_MAX_K + 1, 100, n_dev=1) == "chunked_vmap"
    assert backends_mod.select_backend(
        8, backends_mod.AUTO_VMAP_MAX_ELEMS, n_dev=1) == "chunked_vmap"
    # memory-heavy multi-device runs still shard (the backend self-chunks
    # per shard rather than falling back to a single device)
    assert backends_mod.select_backend(
        4 * backends_mod.AUTO_VMAP_MAX_K + 4, 100, n_dev=4) == "shard_map"


def test_shard_map_chunked_matches_vmap(vmap_ref):
    """Per-shard chunking (chunk=2 with k=6 pads to a n_dev*chunk multiple)
    must not change results — it only bounds per-device memory."""
    r = pop.pop_solve(_problem(), 6, strategy="stratified",
                      backend="shard_map", solver_kw=SOLVER_KW,
                      backend_opts=dict(chunk=2))
    np.testing.assert_allclose(r.alloc, vmap_ref.alloc, atol=1e-6)
    np.testing.assert_array_equal(r.iterations, vmap_ref.iterations)


def test_compat_shard_map_runs():
    """The compat shim maps ``check=`` onto whatever this JAX calls it."""
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("m",))
    fn = compat.shard_map(lambda a: a * 2.0, mesh=mesh, in_specs=(P(),),
                          out_specs=P(), check=False)
    out = jax.jit(fn)(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


def test_multi_device_padding_subprocess():
    """k=6 on a forced 4-device host mesh: shard_map and pmap pad to 8
    lanes (no mesh shrinking, no idle device) and still match vmap."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        assert jax.device_count() == 4, jax.device_count()
        from repro.core import pop, select_backend
        from repro.problems.cluster_scheduling import (GavelProblem,
                                                       make_cluster_workload)
        wl = make_cluster_workload(30, num_workers=(8, 8, 8), seed=5)
        prob = GavelProblem(wl, space_sharing=False)
        kw = dict(max_iters=4_000, tol_primal=1e-4, tol_gap=1e-4)
        ref = pop.pop_solve(prob, 6, strategy="stratified", backend="vmap",
                            solver_kw=kw)
        for b in ("shard_map", "pmap"):
            r = pop.pop_solve(prob, 6, strategy="stratified", backend=b,
                              solver_kw=kw)
            np.testing.assert_allclose(r.alloc, ref.alloc, atol=1e-6)
        assert select_backend(6) == "shard_map"
        print("multi-device padding ok")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=repro_env())
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "multi-device padding ok" in r.stdout
