"""Partitioner + replication + reduce invariants (property-based)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    random_partition, stratified_partition, stratified_partition_multidim,
    clustered_partition, skewed_partition, similarity_report,
    plan_replication, replicated_partition,
    coalesce_concat, coalesce_replicated,
)


def _check_exact_cover(idx, n):
    ids = idx[idx >= 0]
    assert sorted(ids.tolist()) == list(range(n)), "each entity appears exactly once"


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), k=st.integers(1, 16), seed=st.integers(0, 10_000))
def test_random_partition_exact_cover_and_balance(n, k, seed):
    idx = random_partition(n, k, seed)
    _check_exact_cover(idx, n)
    sizes = (idx >= 0).sum(axis=1)
    assert sizes.max() - sizes.min() <= 1, "balanced within 1"


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 400), k=st.integers(1, 8), seed=st.integers(0, 10_000))
def test_stratified_partition_cover(n, k, seed):
    rng = np.random.default_rng(seed)
    scores = rng.exponential(size=n)
    idx = stratified_partition(scores, k)
    _check_exact_cover(idx, n)
    # stratified: per-bin mean load within 25% of global for reasonable sizes
    if n >= 64 * k:
        means = [scores[row[row >= 0]].mean() for row in idx]
        assert max(means) / max(min(means), 1e-9) < 1.35


def test_stratified_beats_skewed_similarity():
    """The paper's core claim about partition quality, as a testable
    invariant: stratified splits are closer to the global distribution."""
    rng = np.random.default_rng(0)
    n, k = 1024, 8
    group = rng.integers(0, k, n)                  # skew driver
    attrs = np.stack([rng.exponential(1 + 3 * group), rng.normal(size=n)], 1)
    strat = stratified_partition_multidim(attrs, k)
    skew = skewed_partition(group, k)
    s_strat = similarity_report(attrs, strat)
    s_skew = similarity_report(attrs, skew)
    assert s_strat["max_mean_dist"] < 0.5 * s_skew["max_mean_dist"]


def test_clustered_partition_spreads_types():
    rng = np.random.default_rng(1)
    n, k = 600, 6
    labels = rng.integers(0, 3, n)
    idx = clustered_partition(labels, k)
    _check_exact_cover(idx, n)
    for lab in range(3):
        counts = [(labels[row[row >= 0]] == lab).sum() for row in idx]
        assert max(counts) - min(counts) <= 2


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 200), k=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_replication_distinct_bins(n, k, seed):
    """Replicas of one entity must land on distinct sub-problems."""
    rng = np.random.default_rng(seed)
    demands = rng.exponential(size=n)
    demands[0] = demands.sum()                    # one Taylor-Swift entity
    plan = plan_replication(demands, k, threshold=0.5)
    idx = replicated_partition(plan, demands, k, seed)
    # exact cover of replicas
    ids = idx[idx >= 0]
    assert sorted(ids.tolist()) == list(range(plan.n_expanded))
    for e in range(n):
        bins = [b for b in range(k) for r in idx[b][idx[b] >= 0]
                if plan.replica_entity[r] == e]
        assert len(bins) == len(set(bins))


def test_replication_scales_sum_to_one():
    demands = np.array([10.0, 1.0, 1.0, 1.0])
    plan = plan_replication(demands, 4, threshold=0.5)
    for e in range(4):
        s = plan.replica_scale[plan.replica_entity == e].sum()
        np.testing.assert_allclose(s, 1.0)


def test_coalesce_concat_roundtrip():
    rng = np.random.default_rng(2)
    n, k = 37, 4
    idx = random_partition(n, k, 0)
    vals = rng.normal(size=(k, idx.shape[1], 3))
    out = coalesce_concat(vals, idx, n)
    for b in range(k):
        for s, e in enumerate(idx[b]):
            if e >= 0:
                np.testing.assert_allclose(out[e], vals[b, s])


def test_coalesce_replicated_sums():
    demands = np.array([5.0, 1.0, 1.0])
    plan = plan_replication(demands, 3, threshold=0.5)
    idx = replicated_partition(plan, demands, 3, 0)
    vals = np.ones((3, idx.shape[1], 2))
    vals[idx < 0] = 0.0
    out = coalesce_replicated(vals, idx, plan)
    n_rep = np.array([(plan.replica_entity == e).sum() for e in range(3)])
    np.testing.assert_allclose(out[:, 0], n_rep.astype(float))
