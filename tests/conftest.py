"""Shared pytest config: deterministic hypothesis profile (reproducible CI
across runs — property tests explore a fixed corpus)."""

from hypothesis import settings

settings.register_profile("ci", derandomize=True, deadline=None)
settings.load_profile("ci")
