"""Shared pytest config: deterministic hypothesis profile (reproducible CI
across runs — property tests explore a fixed corpus).

``hypothesis`` is optional: minimal environments still collect and run the
160+ non-property tests; property tests skip via the ``_hypothesis_compat``
shim the test modules import instead of ``hypothesis`` directly."""

try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", derandomize=True, deadline=None)
    settings.load_profile("ci")
