"""Launch-layer units: HLO collective parser, input specs, shape policy,
mesh planning — everything the dry-run/roofline pipeline depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as sp
from repro.launch.hlo_stats import active_param_counts, collective_bytes


# ---------------------------------------------------------------------------
# collective_bytes parser
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule jit_step
ENTRY %main {
  %all-reduce.37 = f32[2,4096,4096]{2,1,0} all-reduce(%fusion.1), channel_id=7
  %misleading-name = f32[8,8]{1,0} add(%all-reduce.37, %all-reduce.37)
  %ag = bf16[16,128]{1,0} all-gather(%p0), dimensions={0}
  %t = (f32[4,4]{1,0}, f32[2]{0}) all-reduce(%a, %b), channel_id=9
  %ar2 = f32[10]{0} all-reduce-start(%x), channel_id=11
  %done = f32[10]{0} all-reduce-done(%ar2)
  %cp = u8[32]{0} collective-permute(%y), source_target_pairs={{0,1}}
  ROOT %r = f32[2]{0} reduce-scatter(%z), dimensions={0}
}
"""


def test_collective_parser_counts_results_only():
    out = collective_bytes(HLO_SAMPLE)
    ar = 2 * 4096 * 4096 * 4 + (4 * 4 * 4 + 2 * 4) + 10 * 4   # .37 + tuple + start
    assert out["all-reduce"] == ar
    assert out["all-gather"] == 16 * 128 * 2
    assert out["collective-permute"] == 32
    assert out["reduce-scatter"] == 2 * 4
    # `add` of an all-reduce-named operand must NOT count;
    # `-done` must not double count
    assert out["count"] == 6
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


# ---------------------------------------------------------------------------
# specs / shape policy
# ---------------------------------------------------------------------------

def test_long_500k_skip_policy_matches_design():
    runnable = {a: sp.cell_is_runnable(get_config(a), sp.SHAPES["long_500k"])[0]
                for a in ARCH_IDS}
    assert runnable == {
        "h2o_danube3_4b": True,      # SWA
        "gemma3_4b": True,           # 5:1 local:global
        "gemma2_27b": False,         # alternating -> global full attention
        "llama3_8b": False,
        "mixtral_8x22b": True,       # SWA
        "qwen2_moe_a2_7b": False,
        "zamba2_2_7b": True,         # hybrid SSM
        "seamless_m4t_medium": False,
        "chameleon_34b": False,
        "xlstm_350m": True,          # recurrent state
    }


def test_batch_specs_shapes():
    cfg = get_config("llama3_8b")
    cell = sp.SHAPES["train_4k"]
    b = sp.batch_specs(cfg, cell)
    assert b["tokens"].shape == (256, 4096)
    assert b["labels"].shape == (256, 4096)
    assert "enc_embeddings" not in b
    # enc-dec arch gets encoder memory
    cfg2 = get_config("seamless_m4t_medium")
    b2 = sp.batch_specs(cfg2, cell)
    assert b2["enc_embeddings"].shape == (256, sp.ENC_MEMORY_LEN, 1024)


def test_decode_specs_cache_sized_by_window():
    """SWA archs allocate ring buffers of window size, not seq size."""
    cfg = get_config("h2o_danube3_4b")       # window 4096 everywhere
    cell = sp.SHAPES["long_500k"]
    _, cache, _ = sp.decode_specs(cfg, cell)
    kv_leaves = [l for l in jax.tree.leaves(cache) if l.ndim == 5]
    assert kv_leaves and all(l.shape[2] == 4096 for l in kv_leaves)
    # full-attention arch at 32k allocates the full 32k
    cfg2 = get_config("llama3_8b")
    _, cache2, _ = sp.decode_specs(cfg2, sp.SHAPES["decode_32k"])
    kv2 = [l for l in jax.tree.leaves(cache2) if l.ndim == 5]
    assert kv2 and all(l.shape[2] == 32_768 for l in kv2)


def test_microbatching_policy():
    cell = sp.SHAPES["train_4k"]
    assert sp.microbatches_for(cell, n_dp=16) == 8      # 256/16 -> cap 8
    assert sp.microbatches_for(cell, n_dp=32) == 8
    assert sp.microbatches_for(sp.SHAPES["decode_32k"], 16) == 1


def test_active_params_moe_vs_dense():
    mix = active_param_counts(get_config("mixtral_8x22b"))
    assert mix["total"] > 120e9                          # ~140B total
    assert mix["active"] < 0.45 * mix["total"]           # top-2 of 8
    dense = active_param_counts(get_config("llama3_8b"))
    assert dense["active"] == dense["total"]


# ---------------------------------------------------------------------------
# mesh planning
# ---------------------------------------------------------------------------

def test_make_host_mesh_uses_available_devices():
    from repro.launch.mesh import make_host_mesh, mesh_chip_count
    m = make_host_mesh(model_parallel=1)
    assert mesh_chip_count(m) == len(jax.devices())
