"""POP quickstart: split a traffic-engineering LP, solve the parts in one
batched PDHG call, coalesce — and compare against the full solve + CSPF.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import pop, skewed_partition
from repro.problems.traffic_engineering import (
    TrafficProblem, cspf_heuristic, k_shortest_paths, make_demands,
    make_topology)

SOLVER_KW = dict(max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)


def main():
    print("== POP quickstart: WAN traffic engineering ==")
    topo = make_topology(n_nodes=120, target_edges=280, seed=0)
    pairs, demand = make_demands(topo, 4_000, seed=1)
    paths = k_shortest_paths(topo, pairs, n_paths=4, max_len=32, seed=2)
    prob = TrafficProblem(topo, pairs, demand, paths)

    full, res, t_full, _ = pop.solve_full(prob, solver_kw=SOLVER_KW)
    ev_full = prob.evaluate(full)
    print(f"full LP     : flow={ev_full['total_flow']:8.1f}  "
          f"t={t_full:6.2f}s  max_util={ev_full['max_edge_util']:.3f}")

    for k in (4, 16):
        r = pop.pop_solve(prob, k, strategy="random", solver_kw=SOLVER_KW)
        ev = prob.evaluate(r.alloc)
        print(f"POP-{k:<2d}      : flow={ev['total_flow']:8.1f}  "
              f"t={r.solve_time_s:6.2f}s  "
              f"({ev['total_flow']/ev_full['total_flow']:6.1%} of optimal, "
              f"{t_full/r.solve_time_s:4.1f}x faster)")

    f = cspf_heuristic(prob)
    ev = prob.evaluate(f)
    print(f"CSPF        : flow={ev['total_flow']:8.1f}  "
          f"({ev['total_flow']/ev_full['total_flow']:6.1%} of optimal)")

    # the paper's Fig. 6 failure mode, in three lines:
    idx = skewed_partition(prob.source_groups(), 16)
    r = pop.pop_solve(prob, 16, partition_idx=idx, solver_kw=SOLVER_KW)
    ev = prob.evaluate(r.alloc)
    print(f"POP-16 skew : flow={ev['total_flow']:8.1f}  "
          f"({ev['total_flow']/ev_full['total_flow']:6.1%} of optimal) "
          f"<- why splits must be distributionally similar")


if __name__ == "__main__":
    main()
