"""POP quickstart — the one public API.

Split a traffic-engineering LP with a PopService session, solve the parts
in one batched PDHG call, coalesce — and compare against the full solve +
CSPF.  Then the same session warm-starts a drifted re-solve, and the same
service places MoE experts: one door for every scenario.

    PYTHONPATH=src python examples/quickstart.py [--fast]
"""

import argparse

from repro.core import ExecConfig, SolveConfig, pop, skewed_partition
from repro.domains import make_placement_instance
from repro.problems.traffic_engineering import (
    TrafficProblem, cspf_heuristic, k_shortest_paths, make_demands,
    make_topology)
from repro.service import PopService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny sizes (smoke-test mode)")
    args = ap.parse_args()
    n_nodes, n_edges, n_dem = (60, 140, 600) if args.fast else (120, 280, 4_000)
    iters = 2_000 if args.fast else 8_000

    print("== POP quickstart: WAN traffic engineering ==")
    topo = make_topology(n_nodes=n_nodes, target_edges=n_edges, seed=0)
    pairs, demand = make_demands(topo, n_dem, seed=1)
    paths = k_shortest_paths(topo, pairs, n_paths=4, max_len=32, seed=2)
    prob = TrafficProblem(topo, pairs, demand, paths)

    exec_cfg = ExecConfig(solver_kw=dict(max_iters=iters, tol_primal=1e-4,
                                         tol_gap=1e-4))
    fr = pop.solve_full_ex(prob, exec_cfg=exec_cfg)
    full, t_full = fr.alloc, fr.solve_time_s
    ev_full = prob.evaluate(full)
    print(f"full LP     : flow={ev_full['total_flow']:8.1f}  "
          f"t={t_full:6.2f}s  max_util={ev_full['max_edge_util']:.3f}")

    # the service: one long-lived object; a session per tenant/problem
    service = PopService()
    for k in (4, 16):
        sess = service.session(f"net-k{k}", prob,
                               solve=SolveConfig(k=k, strategy="random"),
                               exec=exec_cfg)
        r = sess.step(prob)
        ev = r.metrics
        print(f"POP-{k:<2d}      : flow={ev['total_flow']:8.1f}  "
              f"t={r.solve_time_s:6.2f}s  "
              f"({ev['total_flow']/ev_full['total_flow']:6.1%} of optimal, "
              f"{t_full/max(r.solve_time_s, 1e-9):4.1f}x faster; "
              f"ran backend={r.backend} engine={r.engine})")

    # online: demands drift, the SAME session re-solves warm — no result
    # hand-carrying, the session owns the plan and the iterates
    sess = service.session("net-k4")
    drifted = TrafficProblem(topo, pairs, demand * 1.05, paths)
    r = sess.step(drifted)
    print(f"warm re-tick: flow={r.metrics['total_flow']:8.1f}  "
          f"t={r.solve_time_s:6.2f}s  plan_cache={r.plan_cache} "
          f"warm_fraction={r.warm_fraction:.2f}")

    f = cspf_heuristic(prob)
    ev = prob.evaluate(f)
    print(f"CSPF        : flow={ev['total_flow']:8.1f}  "
          f"({ev['total_flow']/ev_full['total_flow']:6.1%} of optimal)")

    # the paper's Fig. 6 failure mode, in three lines (documented
    # internals: the staged pipeline under the service):
    idx = skewed_partition(prob.source_groups(), 16)
    r = pop.solve_instance(prob, SolveConfig(k=16), exec_cfg,
                           partition_idx=idx)
    ev = prob.evaluate(r.alloc)
    print(f"POP-16 skew : flow={ev['total_flow']:8.1f}  "
          f"({ev['total_flow']/ev_full['total_flow']:6.1%} of optimal) "
          f"<- why splits must be distributionally similar")

    # same service, different scenario: MoE expert placement through the
    # domain registry (experts -> devices, gate load under compute caps)
    inst = make_placement_instance(64, 8, seed=0)
    r = service.session("moe-fleet", inst).step(inst)
    print(f"MoE place   : served={r.metrics['served_fraction']:.1%} of gate "
          f"load, moved {r.metrics['n_moved']} experts "
          f"(k={r.k}, plan_cache={r.plan_cache})")


if __name__ == "__main__":
    main()
