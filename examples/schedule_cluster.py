"""Fleet scheduling driver: a PopService session over the registered
``gavel`` domain allocating accelerator time to training jobs drawn from
the 10 assigned architectures — the new one-door API for the scheduler
(the legacy ``GavelScheduler`` class forwards onto exactly this).

    PYTHONPATH=src python examples/schedule_cluster.py [--fast]
"""

import argparse

import numpy as np

from repro.configs import ARCH_IDS
from repro.core import ExecConfig, SolveConfig
from repro.domains import GavelInstance
from repro.problems.cluster_scheduling import ClusterWorkload
from repro.service import PopService


def fleet_workload(throughputs, priorities, workers=(256, 256, 256)):
    T = np.stack(throughputs)
    n = T.shape[0]
    return ClusterWorkload(
        T=T, w=np.asarray(priorities), z=np.ones(n),
        num_workers=np.asarray(workers, np.float64),
        interference=np.full(n, 0.8), job_type=np.zeros(n, np.int64))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny fleet (smoke-test mode)")
    args = ap.parse_args()
    n_jobs = 48 if args.fast else 240
    iters = 2_000 if args.fast else 10_000

    print("== POP-Gavel cluster scheduler (PopService session) ==")
    rng = np.random.default_rng(0)
    names = [f"{ARCH_IDS[i % len(ARCH_IDS)]}-{i:03d}" for i in range(n_jobs)]
    thpt = [np.abs(rng.normal([1.0, 0.6, 0.8], 0.2)) + 0.05
            for _ in range(n_jobs)]
    prio = [float(rng.choice([1.0, 2.0, 4.0], p=[0.7, 0.2, 0.1]))
            for _ in range(n_jobs)]
    eids = np.arange(n_jobs)

    service = PopService()
    session = service.session(
        "training-fleet", domain="gavel",
        solve=SolveConfig(k=8, strategy="stratified", min_per_sub=8),
        exec=ExecConfig(solver_kw=dict(max_iters=iters, tol_primal=1e-4,
                                       tol_gap=1e-4, equilibrate=True)))

    # round 1: cold
    r = session.step(GavelInstance(fleet_workload(thpt, prio), job_ids=eids))
    rho = np.atleast_1d(r.alloc)
    print(f"jobs={n_jobs}  round_time={r.solve_time_s:.2f}s  k={r.k}  "
          f"min_rho={rho.min():.3f}  mean_rho={rho.mean():.3f}  "
          f"(ran backend={r.backend} engine={r.engine})")

    # round 2: a straggling job reports poor measured throughput -> the
    # session re-solves WARM from its own carried state (no result
    # threading by the caller)
    thpt[0] = 0.7 * thpt[0] + 0.3 * np.array([0.2, 0.1, 0.15])
    r2 = session.step(GavelInstance(fleet_workload(thpt, prio),
                                    job_ids=eids))
    rho2 = np.atleast_1d(r2.alloc)
    print(f"after throughput update: min_rho={rho2.min():.3f} "
          f"round_time={r2.solve_time_s:.2f}s plan_cache={r2.plan_cache} "
          f"warm_fraction={r2.warm_fraction:.2f}")

    # round 3: churn — 4 jobs finish, 4 arrive; stable ids keep survivors warm
    keep = np.arange(n_jobs) >= 4
    thpt = [t for t, k in zip(thpt, keep) if k] + [
        np.abs(rng.normal([1.0, 0.6, 0.8], 0.2)) + 0.05 for _ in range(4)]
    prio = [p for p, k in zip(prio, keep) if k] + [1.0] * 4
    eids = np.concatenate([eids[keep], n_jobs + np.arange(4)])
    r3 = session.step(GavelInstance(fleet_workload(thpt, prio),
                                    job_ids=eids))
    print(f"after churn (4 out / 4 in): plan_cache={r3.plan_cache} "
          f"warm_fraction={r3.warm_fraction:.2f}")
    print("sample allocations (job -> time-fraction rho):")
    for i in range(5):
        print(f"  {names[i+4]:28s} rho={float(np.atleast_1d(r3.alloc)[i]):.3f}")
    print(f"service stats: {service.stats()}")


if __name__ == "__main__":
    main()
