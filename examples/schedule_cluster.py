"""Fleet scheduling driver: the POP-Gavel scheduler allocating accelerator
time to training jobs drawn from the 10 assigned architectures.

    PYTHONPATH=src python examples/schedule_cluster.py
"""

import numpy as np

from repro.configs import ARCH_IDS
from repro.sched import GavelScheduler, JobSpec, SchedulerConfig


def main():
    print("== POP-Gavel cluster scheduler ==")
    sched = GavelScheduler(SchedulerConfig(
        num_workers=(256, 256, 256), pop_k=8,
        solver_kw=dict(max_iters=10_000, tol_primal=1e-4, tol_gap=1e-4)))

    rng = np.random.default_rng(0)
    for i in range(240):
        arch = ARCH_IDS[i % len(ARCH_IDS)]
        sched.submit(JobSpec(
            job_id=f"{arch}-{i:03d}",
            arch=arch,
            priority=float(rng.choice([1.0, 2.0, 4.0], p=[0.7, 0.2, 0.1])),
            throughputs=np.abs(rng.normal([1.0, 0.6, 0.8], 0.2)) + 0.05,
        ))

    alloc = sched.allocate()
    rep = sched.fairness_report()
    print(f"jobs={rep['n_jobs']}  round_time={rep['round_time_s']:.2f}s  "
          f"min_rho={rep['min_norm_throughput']:.3f}  "
          f"mean_rho={rep['mean_norm_throughput']:.3f}")

    # a straggling job reports poor measured throughput -> next round adapts
    sched.report_throughput(list(alloc)[0], np.array([0.2, 0.1, 0.15]))
    sched.allocate()
    rep2 = sched.fairness_report()
    print(f"after throughput update: min_rho={rep2['min_norm_throughput']:.3f} "
          f"round_time={rep2['round_time_s']:.2f}s")
    print("sample allocations (job -> time-fraction rho):")
    for jid in list(alloc)[:5]:
        print(f"  {jid:28s} rho={float(np.atleast_1d(alloc[jid])[0]):.3f}")


if __name__ == "__main__":
    main()
