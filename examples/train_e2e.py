"""End-to-end training driver with the full production substrate:

  data pipeline -> sharded train step (grad accumulation, bf16 compute)
  -> AdamW -> async checkpointing -> SIMULATED MID-RUN FAILURE ->
  restart from latest checkpoint (+ data-cursor restore) -> elastic
  remesh plan -> loss curve continues exactly.

Default config is CPU-budgeted (~10M params, 120 steps, minutes); pass
``--model-scale full`` for the ~100M-class run (hours on one CPU core —
the same driver, bigger dims).

    PYTHONPATH=src python examples/train_e2e.py
"""

import argparse
import os
import shutil
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.models import init_params
from repro.models.transformer import ArchCfg, BlockCfg, Segment
from repro.sched.elastic import HeartbeatMonitor, plan_remesh
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainConfig, make_train_step

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "e2e_ckpt")


def model_cfg(scale: str) -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=None)
    if scale == "full":       # ~100M-class
        return ArchCfg(name="e2e-100m", d_model=640, n_heads=10, n_kv=5,
                       head_dim=64, d_ff=2560, vocab=32_000,
                       segments=(Segment(period=(block,), n_periods=12),))
    return ArchCfg(name="e2e-10m", d_model=256, n_heads=8, n_kv=4,
                   head_dim=32, d_ff=1024, vocab=8_000,
                   segments=(Segment(period=(block,), n_periods=4),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-scale", default="small", choices=["small", "full"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--fail-at", type=int, default=60,
                    help="simulate a worker failure at this step")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    shutil.rmtree(CKPT_DIR, ignore_errors=True)
    cfg = model_cfg(args.model_scale)
    B, S = (8, 128) if args.model_scale == "small" else (8, 512)
    tcfg = TrainConfig(n_microbatches=2, adamw=opt_mod.AdamWConfig(
        peak_lr=3e-3, warmup_steps=20, total_steps=args.steps))

    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"== e2e training: {cfg.name} ({n_params/1e6:.1f}M params, "
          f"{args.steps} steps, B={B} S={S}) ==")

    opt = opt_mod.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh=None))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=B, seq=S, seed=1)
    ck = Checkpointer(CKPT_DIR)
    hb = HeartbeatMonitor(timeout_s=5.0)

    def run_until(params, opt, pipe, start, stop, tag):
        it = iter(pipe)
        losses = []
        for s in range(start, stop):
            batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            # one-shot driver: jitted once, reused  # popcheck: disable=retrace-hazard
            params, opt, m = step_fn(params, opt, batch)
            hb.beat(0)
            losses.append(float(m["loss"]))
            if s % args.ckpt_every == 0 and s > 0:
                ck.save_async(s, {"params": params, "opt": opt},
                              extras={"pipeline": pipe.state(), "step": s})
            if s % 20 == 0:
                print(f"  [{tag}] step {s:4d} loss={losses[-1]:.4f} "
                      f"({time.perf_counter()-t0:.2f}s/step)")
        return params, opt, losses

    params, opt, losses_a = run_until(params, opt, pipe, 0, args.fail_at,
                                      "run-1")
    ck.wait()

    # ---- simulated failure + restart -----------------------------------
    print(f"  !! simulating worker failure at step {args.fail_at}; "
          f"restarting from latest checkpoint")
    latest = ck.latest()
    plan = plan_remesh(n_alive=255 * 2, model_parallel=16)   # 1 chip died
    print(f"  elastic plan after failure: mesh={plan['mesh_shape']} "
          f"spares={plan['spares']}")
    restored, extras = ck.restore(latest, {"params": params, "opt": opt})
    pipe2 = TokenPipeline(vocab=cfg.vocab, batch=B, seq=S, seed=1)
    pipe2.restore(extras["pipeline"])
    print(f"  restored step {extras['step']} (data cursor "
          f"{extras['pipeline']['cursor']})")

    params, opt, losses_b = run_until(restored["params"], restored["opt"],
                                      pipe2, extras["step"], args.steps,
                                      "run-2")
    full = losses_a[: extras["step"]] + losses_b
    print(f"final loss {full[-1]:.4f} (start {full[0]:.4f}) — "
          f"{'DECREASED' if full[-1] < full[0] else 'flat'} across restart")


if __name__ == "__main__":
    main()
