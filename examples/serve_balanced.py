"""End-to-end serving driver: batched decode of a small LM across several
replica groups, with a PopService session (the registered ``load_balance``
domain) placing request shards onto replicas — the paper's technique
running in the serving path, through the one public API.

    PYTHONPATH=src python examples/serve_balanced.py [--fast]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import ExecConfig, SolveConfig
from repro.domains import BalanceInstance
from repro.models import init_cache, init_params
from repro.serve.engine import ServeConfig, make_serve_step
from repro.service import PopService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer groups + decode steps (smoke-test mode)")
    args = ap.parse_args()
    n_groups = 24 if args.fast else 64
    decode_cap = 4 if args.fast else 16

    print("== POP-balanced batched serving ==")
    cfg = get_reduced("xlstm_350m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_replicas = 4
    rng = np.random.default_rng(0)

    # request groups with heavy-tailed load (tokens to generate).  Stable
    # session ids per group let the balancer session's warm state survive
    # group churn (sessions finishing, sessions arriving).
    load = np.minimum(rng.zipf(1.9, n_groups), 60).astype(np.float64)
    current = rng.integers(0, n_replicas, n_groups)   # sticky sessions
    group_ids = np.arange(n_groups)
    next_id = n_groups

    # the balancer is a long-lived session: request groups = shards,
    # replicas = servers; warm state lives INSIDE it
    service = PopService()
    balancer = service.session(
        "decode-balancer", domain="load_balance",
        solve=SolveConfig(k=2),
        exec=ExecConfig(solver_kw=dict(max_iters=6_000)))

    res = balancer.step(BalanceInstance(load=load, n_targets=n_replicas,
                                        current=current, eps_frac=0.25,
                                        ids=group_ids))
    print(f"balancer: {n_groups} request groups -> {n_replicas} replicas "
          f"in {res.solve_time_s:.2f}s; moved "
          f"{int((res.alloc != current).sum())} sticky groups; "
          f"max load dev {res.metrics['max_load_dev']:.2f} "
          f"(ran backend={res.backend} engine={res.engine})")

    # tick 2: loads drift a few percent -> warm-started re-solve picks
    # up from the previous PDHG iterates instead of cold
    load2 = load * rng.uniform(0.95, 1.05, n_groups)
    res2 = balancer.step(BalanceInstance(load=load2, n_targets=n_replicas,
                                         current=res.alloc, eps_frac=0.25,
                                         ids=group_ids))
    print(f"warm tick: re-balanced in {res2.solve_time_s:.2f}s; moved "
          f"{int((res2.alloc != res.alloc).sum())} groups; "
          f"plan_cache {res2.plan_cache}; "
          f"warm_fraction {res2.warm_fraction:.2f}")

    # tick 3: CHURN — sessions finish, new ones arrive.  The warm state
    # still chains: surviving groups are matched by id and their iterates
    # remapped onto the new tick's sub-problems.
    n_churn = max(2, n_groups // 8)
    done = rng.choice(n_groups, n_churn, replace=False)
    keep = np.setdiff1d(np.arange(n_groups), done)
    arrivals = np.minimum(rng.zipf(1.9, n_churn), 60).astype(np.float64)
    load3 = np.concatenate([load2[keep], arrivals])
    cur3 = np.concatenate([res2.alloc[keep],
                           rng.integers(0, n_replicas, n_churn)])
    group_ids = np.concatenate([group_ids[keep],
                                next_id + np.arange(n_churn)])
    next_id += n_churn
    res3 = balancer.step(BalanceInstance(load=load3, n_targets=n_replicas,
                                         current=cur3, eps_frac=0.25,
                                         ids=group_ids))
    print(f"churn tick: {n_churn} done / {n_churn} arrived; re-balanced in "
          f"{res3.solve_time_s:.2f}s; plan_cache {res3.plan_cache}; "
          f"warm_fraction {res3.warm_fraction:.2f} "
          f"(survivors warm, arrivals start from priors)")
    placement, load = res3.alloc, load3

    # serve: each replica decodes its assigned groups as one batch
    scfg = ServeConfig(batch=1, max_seq=128)
    step = jax.jit(make_serve_step(cfg, scfg))
    total_tokens = 0
    t0 = time.perf_counter()
    for r in range(n_replicas):
        groups = np.flatnonzero(placement == r)
        if groups.size == 0:
            continue
        B = int(groups.size)
        cache = init_cache(cfg, B, 128)
        tok = jnp.zeros((B, 1), jnp.int32)
        steps = int(load[groups].max())
        for _ in range(min(steps, decode_cap)):
            # one-shot driver: step is jitted once per process, the loop
            # reuses the compilation  # popcheck: disable=retrace-hazard
            tok, cache = step(params, cache, tok)
            total_tokens += B
        print(f"  replica {r}: batch={B:3d} groups, "
              f"load={load[groups].sum():6.0f}")
    dt = time.perf_counter() - t0
    print(f"decoded {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.0f} tok/s on CPU)")


if __name__ == "__main__":
    main()
