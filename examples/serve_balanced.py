"""End-to-end serving driver: batched decode of a small LM across several
replica groups, with POP (the paper's load-balancing MILP) placing request
shards onto replicas — the paper's technique running in the serving path.

    PYTHONPATH=src python examples/serve_balanced.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import init_cache, init_params
from repro.serve.engine import ServeConfig, balance_requests, make_serve_step


def main():
    print("== POP-balanced batched serving ==")
    cfg = get_reduced("xlstm_350m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_replicas = 4
    rng = np.random.default_rng(0)

    # 64 request groups with heavy-tailed load (tokens to generate).
    # Stable session ids per group: what lets the balancer's warm state
    # survive group churn (sessions finishing, sessions arriving).
    n_groups = 64
    load = np.minimum(rng.zipf(1.9, n_groups), 60).astype(np.float64)
    current = rng.integers(0, n_replicas, n_groups)   # sticky sessions
    group_ids = np.arange(n_groups)
    next_id = n_groups

    # POP load balancer: request groups = shards, replicas = servers
    res = balance_requests(load, n_replicas, current, pop_k=2,
                           solver_kw=dict(max_iters=6_000),
                           group_ids=group_ids)
    print(f"balancer: {n_groups} request groups -> {n_replicas} replicas "
          f"in {res.solve_time_s:.2f}s; moved {res.moved} sticky groups; "
          f"max load dev {res.max_load_dev:.2f}")

    # tick 2: loads drift a few percent -> warm-started re-solve picks
    # up from the previous PDHG iterates instead of cold
    load2 = load * rng.uniform(0.95, 1.05, n_groups)
    res2 = balance_requests(load2, n_replicas, res.placement, pop_k=2,
                            solver_kw=dict(max_iters=6_000), warm=res,
                            group_ids=group_ids)
    print(f"warm tick: re-balanced in {res2.solve_time_s:.2f}s; "
          f"moved {res2.moved} groups; max load dev {res2.max_load_dev:.2f}; "
          f"warm_fraction {res2.warm_fraction:.2f}")

    # tick 3: CHURN — 8 sessions finish, 8 new ones arrive.  The warm
    # state still chains: surviving groups are matched by id and their
    # iterates remapped onto the new tick's sub-problems (PR-2 would have
    # silently fallen back to a cold solve here).
    done = rng.choice(n_groups, 8, replace=False)
    keep = np.setdiff1d(np.arange(n_groups), done)
    arrivals = np.minimum(rng.zipf(1.9, 8), 60).astype(np.float64)
    load3 = np.concatenate([load2[keep], arrivals])
    cur3 = np.concatenate([res2.placement[keep],
                           rng.integers(0, n_replicas, 8)])
    group_ids = np.concatenate([group_ids[keep],
                                next_id + np.arange(8)])
    next_id += 8
    res3 = balance_requests(load3, n_replicas, cur3, pop_k=2,
                            solver_kw=dict(max_iters=6_000), warm=res2,
                            group_ids=group_ids)
    print(f"churn tick: 8 done / 8 arrived; re-balanced in "
          f"{res3.solve_time_s:.2f}s; moved {res3.moved} groups; "
          f"warm_fraction {res3.warm_fraction:.2f} "
          f"(survivors warm, arrivals start from priors)")
    res, load = res3, load3

    # serve: each replica decodes its assigned groups as one batch
    scfg = ServeConfig(batch=1, max_seq=128)
    step = jax.jit(make_serve_step(cfg, scfg))
    total_tokens = 0
    t0 = time.perf_counter()
    for r in range(n_replicas):
        groups = np.flatnonzero(res.placement == r)
        if groups.size == 0:
            continue
        B = int(groups.size)
        cache = init_cache(cfg, B, 128)
        tok = jnp.zeros((B, 1), jnp.int32)
        steps = int(load[groups].max())
        for _ in range(min(steps, 16)):           # cap demo length
            tok, cache = step(params, cache, tok)
            total_tokens += B
        print(f"  replica {r}: batch={B:3d} groups, "
              f"load={load[groups].sum():6.0f}")
    dt = time.perf_counter() - t0
    print(f"decoded {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.0f} tok/s on CPU)")


if __name__ == "__main__":
    main()
