"""End-to-end serving driver: batched decode of a small LM across several
replica groups, with POP (the paper's load-balancing MILP) placing request
shards onto replicas — the paper's technique running in the serving path.

    PYTHONPATH=src python examples/serve_balanced.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import init_cache, init_params
from repro.serve.engine import ServeConfig, balance_requests, make_serve_step


def main():
    print("== POP-balanced batched serving ==")
    cfg = get_reduced("xlstm_350m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_replicas = 4
    rng = np.random.default_rng(0)

    # 64 request groups with heavy-tailed load (tokens to generate)
    n_groups = 64
    load = np.minimum(rng.zipf(1.9, n_groups), 60).astype(np.float64)
    current = rng.integers(0, n_replicas, n_groups)   # sticky sessions

    # POP load balancer: request groups = shards, replicas = servers
    res = balance_requests(load, n_replicas, current, pop_k=2,
                           solver_kw=dict(max_iters=6_000))
    print(f"balancer: {n_groups} request groups -> {n_replicas} replicas "
          f"in {res.solve_time_s:.2f}s; moved {res.moved} sticky groups; "
          f"max load dev {res.max_load_dev:.2f}")

    # next tick: loads drift a few percent -> warm-started re-solve picks
    # up from the previous PDHG iterates instead of cold
    load2 = load * rng.uniform(0.95, 1.05, n_groups)
    res2 = balance_requests(load2, n_replicas, res.placement, pop_k=2,
                            solver_kw=dict(max_iters=6_000), warm=res)
    print(f"warm tick: re-balanced in {res2.solve_time_s:.2f}s; "
          f"moved {res2.moved} groups; max load dev {res2.max_load_dev:.2f}")

    # serve: each replica decodes its assigned groups as one batch
    scfg = ServeConfig(batch=1, max_seq=128)
    step = jax.jit(make_serve_step(cfg, scfg))
    total_tokens = 0
    t0 = time.perf_counter()
    for r in range(n_replicas):
        groups = np.flatnonzero(res.placement == r)
        if groups.size == 0:
            continue
        B = int(groups.size)
        cache = init_cache(cfg, B, 128)
        tok = jnp.zeros((B, 1), jnp.int32)
        steps = int(load[groups].max())
        for _ in range(min(steps, 16)):           # cap demo length
            tok, cache = step(params, cache, tok)
            total_tokens += B
        print(f"  replica {r}: batch={B:3d} groups, "
              f"load={load[groups].sum():6.0f}")
    dt = time.perf_counter() - t0
    print(f"decoded {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.0f} tok/s on CPU)")


if __name__ == "__main__":
    main()
