"""Shared benchmark utilities: timing, CSV emission, result persistence,
and the host launch preset (tcmalloc + forced host device count) that
``scripts/launch.sh`` applies — importable so benchmarks can detect /
apply it programmatically too."""

from __future__ import annotations

import json
import os
import time

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "bench")

# common install locations of gperftools' tcmalloc (Snippet-style
# LD_PRELOAD: malloc-heavy host staging — packing ELL metadata, padding,
# pytree stacking — measurably benefits from a thread-caching allocator)
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def find_tcmalloc() -> str | None:
    """First present tcmalloc shared object, or None.  Used by
    ``scripts/launch.sh`` (via ``python -m benchmarks.common``) so the
    preset degrades to plain malloc on hosts without gperftools."""
    for cand in TCMALLOC_CANDIDATES:
        if os.path.exists(cand):
            return cand
    return None


def configure_host_devices(n: int | None = None) -> int:
    """Set ``--xla_force_host_platform_device_count=N`` (HomebrewNLP-style)
    BEFORE jax initialises, so the shard_map/pmap map backends see N host
    devices on a many-core CPU box instead of one.  Must run before the
    first ``import jax`` in the process; returns the device count used.
    No-op (returns the current setting) when the flag is already present —
    respects an outer ``scripts/launch.sh`` environment."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        for tok in flags.split():
            if "xla_force_host_platform_device_count" in tok:
                return int(tok.split("=")[1])
    if n is None:
        n = os.cpu_count() or 1
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())
    return n


if __name__ == "__main__":       # scripts/launch.sh queries the preset
    print(find_tcmalloc() or "")


def emit(name: str, us_per_call: float, derived: str = ""):
    """Scaffold contract: ``name,us_per_call,derived`` CSV on stdout."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload: dict):
    os.makedirs(RESULT_DIR, exist_ok=True)
    with open(os.path.join(RESULT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
