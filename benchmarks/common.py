"""Shared benchmark utilities: timing, CSV emission, result persistence."""

from __future__ import annotations

import json
import os
import time

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "bench")


def emit(name: str, us_per_call: float, derived: str = ""):
    """Scaffold contract: ``name,us_per_call,derived`` CSV on stdout."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload: dict):
    os.makedirs(RESULT_DIR, exist_ok=True)
    with open(os.path.join(RESULT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
