"""Paper Fig. 3: Gavel max-min fairness with space sharing.

Full LP vs POP-k vs Gandiva-like heuristic: runtime + mean/min normalised
throughput.  Paper claims: 0.3% mean-quality loss at 405x runtime
improvement; heuristic quality far worse (on the fairness metric).

Default scale is CPU-budgeted (single-core container); ``--paper-scale``
runs the full 10^6-job-combination configuration.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import ExecConfig, SolveConfig, pop
from repro.problems.cluster_scheduling import (GavelProblem,
                                               gandiva_heuristic,
                                               make_cluster_workload)
from .common import Timer, emit, save_json

SOLVER_KW = dict(max_iters=12_000, tol_primal=1e-4, tol_gap=1e-4)


def run(n_jobs: int = 448, workers=(256, 256, 256), ks=(4, 8, 16, 32),
        space_sharing: bool = True, seed: int = 0) -> dict:
    wl = make_cluster_workload(n_jobs, num_workers=workers, seed=seed)
    prob = GavelProblem(wl, space_sharing=space_sharing)
    n_combos = n_jobs + n_jobs * (n_jobs - 1) // 2 if space_sharing else n_jobs

    rows = []
    with Timer() as t:
        fr = pop.solve_full_ex(prob, exec_cfg=ExecConfig(solver_kw=SOLVER_KW))
        full, t_solve = fr.alloc, fr.solve_time_s
    ev = prob.evaluate(full)
    full_mean = ev["mean_norm_throughput"]
    rows.append(dict(method="full", k=1, solve_s=t_solve, **ev))
    emit("cluster_sched_full", t_solve * 1e6,
         f"mean={ev['mean_norm_throughput']:.4f};min={ev['min_norm_throughput']:.4f}")

    for k in ks:
        r = pop.solve_instance(prob, SolveConfig(k=k, strategy="stratified"),
                               ExecConfig(solver_kw=SOLVER_KW))
        ev = prob.evaluate(r.alloc)
        speedup = t_solve / r.solve_time_s
        quality = ev["mean_norm_throughput"] / full_mean
        rows.append(dict(method=f"pop{k}", k=k, solve_s=r.solve_time_s,
                         speedup=speedup, rel_quality=quality, **ev))
        emit(f"cluster_sched_pop{k}", r.solve_time_s * 1e6,
             f"speedup={speedup:.1f}x;rel_mean_quality={quality:.4f};"
             f"min={ev['min_norm_throughput']:.4f}")

    with Timer() as t:
        rho_h = gandiva_heuristic(wl, space_sharing=space_sharing)
    ev = prob.evaluate(rho_h)
    rows.append(dict(method="gandiva", k=0, solve_s=t.seconds, **ev))
    emit("cluster_sched_gandiva", t.seconds * 1e6,
         f"mean={ev['mean_norm_throughput']:.4f};min={ev['min_norm_throughput']:.4f}")

    out = {"n_jobs": n_jobs, "n_combos": n_combos, "rows": rows}
    save_json("cluster_scheduling", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true",
                    help="1414 jobs -> 10^6 combos (minutes-to-hours on CPU)")
    ap.add_argument("--n-jobs", type=int, default=None)
    a = ap.parse_args()
    n = a.n_jobs or (1414 if a.paper_scale else 448)
    run(n_jobs=n)


if __name__ == "__main__":
    main()
