"""SLO auto-tuner payoff: tuned sessions vs the static one-size default.

Builds a fast :class:`~repro.tuning.TuningProfile` on scaled-down probes
(the ``scripts/tune.py --fast`` path, in-process), then serves the same
drifting workload twice per domain: once with the static default
``SolveConfig()`` (k=4 for every tenant) and once through
``PopService(profile=...).session(..., slo=SLOTarget(0.02))``.  Reports
steady-state steps/sec and the *realized* quality (domain quality scalar
over a per-round reference full solve) for both — the headline is that
the measured-curve pick meets the 2% SLO while stepping faster than the
static default wherever the domain's curve allows a larger k (cluster
scheduling's flat curve) and holds quality where it does not (traffic's
steep curve).

    PYTHONPATH=src python -m benchmarks.bench_tuning [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import ExecConfig, SolveConfig, pop as pop_mod
from repro.domains import GavelInstance, registry as registry_mod
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import PopService
from repro.tuning import SLOTarget, build_profile, profile_digest
from .common import emit, save_json

SLO = SLOTarget(max_quality_loss=0.02)


def _scenarios(fast: bool, rng):
    """(domain, first instance, drift fn) per benched domain."""
    kw = dict(max_iters=1_500 if fast else 4_000, tol_primal=1e-4,
              tol_gap=1e-4)
    n_jobs = 96 if fast else 256
    n_dem = 160 if fast else 600

    wl = make_cluster_workload(n_jobs, seed=3)
    ginst = GavelInstance(wl, job_ids=np.arange(n_jobs))

    def drift_gavel(inst, rng=rng):
        wl2 = dataclasses.replace(
            inst.wl, T=inst.wl.T * rng.uniform(0.95, 1.05, inst.wl.T.shape))
        return GavelInstance(wl2, job_ids=inst.job_ids)

    topo = make_topology(20, 40, seed=3)
    pairs, dem = make_demands(topo, n_dem, seed=3)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=3)
    tinst = TrafficProblem(topo, pairs, dem, pe)

    def drift_traffic(inst, rng=rng):
        return TrafficProblem(
            inst.topo, inst.pairs,
            inst.demand * rng.uniform(0.97, 1.03, inst.demand.shape[0]),
            inst.path_edges)

    return [("gavel", ginst, drift_gavel, ExecConfig(solver_kw=kw)),
            ("traffic", tinst, drift_traffic, ExecConfig(solver_kw=kw))]


def _ref_quality(spec, inst, exec_cfg):
    """Per-round realized-quality reference: a CONVERGED k=1 full solve
    (the serving arms run capped budgets; the reference must not)."""
    kw = dict(exec_cfg.solver_dict())
    kw["max_iters"] = max(int(kw.get("max_iters", 4_000)) * 4, 8_000)
    ref_cfg = ExecConfig(backend=exec_cfg.backend, engine=exec_cfg.engine,
                         solver_kw=kw)
    problem = spec.make_problem(inst)
    res = pop_mod.solve_full_ex(problem, exec_cfg=ref_cfg)
    alloc = res.alloc
    if spec.round is not None:
        alloc = spec.round(inst, res.alloc)
    return spec.quality_of(spec.metrics_of(inst, problem, alloc))


def run(fast: bool = False, rounds: int = None, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    rounds = rounds or (4 if fast else 8)

    t0 = time.perf_counter()
    profile = build_profile(domains=("gavel", "traffic"), fast=True,
                            seed=seed, measure_launch=False,
                            measure_backends=False)
    profile.digest = profile_digest(profile)   # seal (save_profile's job
    profile_s = time.perf_counter() - t0       # when the artifact is written)
    emit("tuning_profile_build", profile_s * 1e6,
         f"domains={len(profile.domains)}")

    tuned_svc = PopService(profile=profile)
    static_svc = PopService()
    out = {"profile_build_s": round(profile_s, 2), "slo": SLO.max_quality_loss,
           "rounds": rounds, "domains": {}}

    for domain, inst, drift, exec_cfg in _scenarios(fast, rng):
        spec = registry_mod.get(domain)
        arms = {}
        for label, svc, solve, slo in (
                ("static", static_svc, SolveConfig(), None),
                ("tuned", tuned_svc, None, SLO)):
            if slo is None:
                sess = svc.session(f"{domain}-static", inst, domain=domain,
                                   solve=solve, exec=exec_cfg)
            else:
                sess = svc.session(f"{domain}-tuned", inst, domain=domain,
                                   exec=exec_cfg, slo=slo)
            sess.step(inst)               # warmup (cold solve + compiles)
            cur = inst
            t1 = time.perf_counter()
            stepped = []
            for _ in range(rounds):
                cur = drift(cur)
                stepped.append((cur, sess.step(cur)))
            wall = time.perf_counter() - t1
            # realized quality vs the per-round capped full solve
            rels = []
            for step_inst, alloc in stepped:
                q = spec.quality_of(alloc.metrics)
                q_ref = _ref_quality(spec, step_inst, exec_cfg)
                if q is not None and q_ref:
                    rels.append(q / q_ref)
            arms[label] = {
                "steps_per_sec": round(rounds / wall, 3),
                "k": int(stepped[-1][1].k),
                "rel_quality_mean": round(float(np.mean(rels)), 4),
                "meets_slo": bool(np.mean(rels) >= 1.0 - SLO.max_quality_loss),
            }
        speedup = arms["tuned"]["steps_per_sec"] / \
            max(arms["static"]["steps_per_sec"], 1e-9)
        emit(f"tuning_{domain}",
             1e6 / max(arms["tuned"]["steps_per_sec"], 1e-9),
             f"tuned_k={arms['tuned']['k']};static_k={arms['static']['k']};"
             f"speedup={speedup:.2f};"
             f"tuned_rel_q={arms['tuned']['rel_quality_mean']:.3f};"
             f"meets_slo={arms['tuned']['meets_slo']}")
        out["domains"][domain] = {**arms, "tuned_vs_static_speedup":
                                  round(speedup, 3)}

    out["tuned_service_stats"] = {
        k: v for k, v in tuned_svc.stats().items()
        if k in ("slo_violations", "retunes", "steps", "plan_hit_rate")}
    save_json("tuning", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    print(run(fast=args.fast, rounds=args.rounds))
