"""Paper §2.4: runtime scaling in k, per map-step execution backend.

Measures POP map-step runtime vs k on a fixed cluster-scheduling instance
and fits the empirical exponent: the paper predicts superlinear speedup
(k^(2a-1) serial; sub-problems here solve as one vmap batch, so the
observed exponent blends the k^2 variable reduction with PDHG's
iteration-count advantage on smaller, better-conditioned problems).

``--backend`` sweeps execution backends from the ``core/backends.py``
registry (default: vmap, chunked_vmap, shard_map) so the scaling curve is
recorded per backend — the data that justifies ``backend="auto"``'s
selection thresholds on each platform.

``--engine-sweep`` (also part of the default run) A/Bs the PDHG *step
engines*: the generic operator-matvec engine vs the fused dense engine on
batched dense LPs, AND vs the ``fused_structured`` gather/segment-reduce
engine on real Gavel sub-problem stacks (singleton combos — the ISSUE
acceptance signal: structured-fused must never lose to matvec at k >= 2),
plus an in-loop-KKT vs standalone-KKT A/B (convergence checks from
carried half-step products cost zero extra operator passes).  Timings are
min-of-N after a compile warmup, so they measure the steady-state map
step — what an online solver with a jit-cached engine actually pays.

Also benchmarks the PDHG solver itself against scipy (HiGHS) on random
dense LPs — the solver-substrate sanity check.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from scipy.optimize import linprog

from repro.core import (ExecConfig, LinearProgram, SolveConfig,
                        backends as backends_mod, pdhg, pop)
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload
from .common import Timer, emit, save_json

DEFAULT_BACKENDS = ("vmap", "chunked_vmap", "shard_map")
DEFAULT_KS = (1, 2, 4, 8, 16, 32)


def _random_dense_stack(k: int, n: int, mi: int, rng) -> pdhg.OperatorLP:
    """k random bounded-feasible dense LPs, stacked (the fused engine's
    home turf: dense data, block-padded by LinearProgram.build)."""
    lps = []
    for _ in range(k):
        c = rng.normal(size=n)
        G = rng.normal(size=(mi, n))
        h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)
        lps.append(LinearProgram.build(c=c, G=G, h=h,
                                       l=np.zeros(n), u=np.ones(n)))
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[pdhg.dense_ops(lp) for lp in lps])


def _ab_time(fns: dict, batch, repeats: int):
    """Interleaved min-of-N timing of competing jitted solvers on one
    batch: compile-warm every contender first, then interleave the timed
    rounds so machine-load drift hits all contenders equally, keeping the
    min per contender.  The ONE timing protocol for every A/B sweep in
    this file.  Returns (best_seconds, results) keyed like ``fns``."""
    results = {}
    for fn in fns.values():
        jax.block_until_ready(fn(batch).x)           # compile warmup
    best = {name: float("inf") for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            res = fn(batch)
            jax.block_until_ready(res.x)
            best[name] = min(best[name], time.perf_counter() - t0)
            results[name] = res
    return best, results


def engine_sweep(ks=DEFAULT_KS, n: int = 150, mi: int = 90,
                 repeats: int = 9, max_iters: int = 2_000,
                 seed: int = 0) -> list:
    """Fused vs matvec engine on batched dense solves, per k.

    Both engines run the identical algorithm through ``solve_stacked`` via
    the jit-cached map solver, so the delta is pure step-execution cost.
    Returns rows [{engine, k, solve_s, iters}, ...]."""
    rng = np.random.default_rng(seed)
    kw = dict(max_iters=max_iters, tol_primal=1e-6, tol_gap=1e-6)
    rows = []
    for k in ks:
        ops = _random_dense_stack(k, n, mi, rng)
        batch = (ops, *backends_mod.cold_start(ops))
        fns = {
            name: backends_mod.make_map_solver(
                pdhg.dense_K_mv, pdhg.dense_KT_mv, kw,
                name if name == "matvec" else pdhg.fused_dense_engine())
            for name in ("matvec", "fused")
        }
        best, results = _ab_time(fns, batch, repeats)
        for name in fns:
            iters = int(np.asarray(results[name].iterations).sum())
            rows.append(dict(engine=name, k=k, solve_s=best[name],
                             iters=iters))
            emit(f"pop_engine_{name}_k{k}", best[name] * 1e6,
                 f"iters={iters}")
    return rows


def structured_engine_sweep(ks=(1, 2, 4, 8, 16), n_jobs: int = 256,
                            repeats: int = 7, max_iters: int = 2_000,
                            seed: int = 0) -> list:
    """fused_structured vs matvec on REAL Gavel sub-problem stacks
    (singleton combos — the per-job segment-sum operator), per k.

    ISSUE acceptance: fused_structured must beat matvec at every k >= 2
    (never slower) — its gather-ELL form has no scatters and one launch
    per half-step for the whole stack, where the matvec engine pays k
    vmapped ``segment_sum`` scatter-adds.  Interleaved min-of-N timing.
    Returns rows [{engine, k, solve_s, iters}, ...]."""
    wl = make_cluster_workload(n_jobs, num_workers=(64, 64, 64), seed=seed)
    prob = GavelProblem(wl, space_sharing=False)
    kw = dict(max_iters=max_iters, tol_primal=1e-6, tol_gap=1e-6)
    rows = []
    for k in ks:
        p = pop.plan(prob, k, strategy="stratified")
        ops = pop.build(prob, p)
        batch = (ops, *backends_mod.cold_start(ops))
        fns = {
            name: backends_mod.make_map_solver(
                prob.K_mv, prob.KT_mv, kw,
                name if name == "matvec" else pdhg.fused_structured_engine())
            for name in ("matvec", "fused_structured")
        }
        best, results = _ab_time(fns, batch, repeats)
        for name in fns:
            iters = int(np.asarray(results[name].iterations).sum())
            rows.append(dict(engine=name, k=k, solve_s=best[name],
                             iters=iters))
            emit(f"pop_structured_{name}_k{k}", best[name] * 1e6,
                 f"iters={iters}")
        emit(f"pop_structured_speedup_k{k}", 0.0,
             f"fused_structured_{best['matvec'] / best['fused_structured']:.2f}"
             "x_vs_matvec")
    return rows


def kkt_sweep(k: int = 8, n: int = 150, mi: int = 90, check_every: int = 10,
              budget: int = 1_000, repeats: int = 7, seed: int = 0) -> list:
    """In-loop vs standalone KKT at a fixed iteration budget: the cost of
    convergence checks.  The in-loop path reads the carried half-step
    products (zero extra operator passes); the standalone reference pays 2
    fresh passes per check — at check_every=10 that is ~10% more operator
    applications, all pure overhead.  Same trajectory either way
    (tests/test_engine_conformance.py pins them bit-level)."""
    rng = np.random.default_rng(seed)
    ops = _random_dense_stack(k, n, mi, rng)
    batch = (ops, *backends_mod.cold_start(ops))
    fns = {
        mode: backends_mod.make_map_solver(
            pdhg.dense_K_mv, pdhg.dense_KT_mv,
            dict(max_iters=budget, check_every=check_every,
                 tol_primal=0.0, tol_gap=0.0, kkt=mode), "matvec")
        for mode in ("inloop", "standalone")
    }
    best, _ = _ab_time(fns, batch, repeats)
    saving = 1.0 - best["inloop"] / best["standalone"]
    emit("pop_kkt_inloop", best["inloop"] * 1e6,
         f"standalone_us={best['standalone'] * 1e6:.0f};"
         f"saving={saving * 100:.1f}%;check_every={check_every}")
    return [dict(mode=m, k=k, check_every=check_every, solve_s=t,
                 iters=budget * k) for m, t in best.items()]


def full_engine_sweep(n_demands: int = 30_000, n_jobs: int = 1_000,
                      max_iters: int = 2_000, seed: int = 0) -> list:
    """Paper-scale FULL-problem rows (``--full``): the M-blocked streaming
    engine vs the matvec reference on the unpartitioned baseline — traffic
    at 30k demands and cluster scheduling at 1k jobs, the scale where
    ``engine="auto"`` switches ``solve_full`` onto
    ``fused_structured_full``.  One timed solve per cell at a fixed
    iteration budget (these are minutes-scale solves; min-of-N would just
    repeat the wait), after per-engine compile warmup.  Returns rows
    [{domain, engine, solve_s, iters}, ...]."""
    from repro.problems.traffic_engineering import (TrafficProblem,
                                                    k_shortest_paths,
                                                    make_demands,
                                                    make_topology)
    topo = make_topology(754, 1790, seed=seed)
    pairs, dem = make_demands(topo, n_demands, seed=seed + 1)
    pe = k_shortest_paths(topo, pairs, n_paths=4, max_len=64, seed=seed + 2)
    wl = make_cluster_workload(n_jobs, num_workers=(256, 256, 256),
                               seed=seed)
    cases = {
        "traffic": TrafficProblem(topo, pairs, dem, pe),
        # singleton combos: only the no-space-sharing operator carries the
        # structured metadata the blocked-full engine needs
        "cluster": GavelProblem(wl, space_sharing=False),
    }
    kw = dict(max_iters=max_iters, check_every=200,
              tol_primal=0.0, tol_gap=0.0)
    rows = []
    for domain, prob in cases.items():
        t_by_engine = {}
        for engine in ("matvec", "fused_structured_full"):
            cfg = ExecConfig(engine=engine, solver_kw=kw)
            pop.solve_full_ex(prob, exec_cfg=ExecConfig(
                engine=engine, solver_kw=dict(kw, max_iters=1)))  # warmup
            fr = pop.solve_full_ex(prob, exec_cfg=cfg)
            assert fr.engine == engine, fr.engine
            iters = int(np.asarray(fr.res.iterations).sum())
            t_by_engine[engine] = fr.solve_time_s
            rows.append(dict(domain=domain, engine=engine,
                             solve_s=fr.solve_time_s, iters=iters))
            emit(f"pop_full_{domain}_{engine}", fr.solve_time_s * 1e6,
                 f"iters={iters}")
        emit(f"pop_full_{domain}_speedup", 0.0,
             f"full_{t_by_engine['matvec'] / t_by_engine['fused_structured_full']:.2f}"
             "x_vs_matvec")
    return rows


def run(n_jobs: int = 512, ks=DEFAULT_KS, seed: int = 0,
        backends=DEFAULT_BACKENDS, engines: bool = True) -> dict:
    wl = make_cluster_workload(n_jobs, num_workers=(128, 128, 128), seed=seed)
    prob = GavelProblem(wl, space_sharing=True)
    kw = dict(max_iters=12_000, tol_primal=1e-4, tol_gap=1e-4)
    rows = []
    expos = {}
    # the k=1 baseline is the unpartitioned solve — backend-independent,
    # so run it once and share it across the sweep
    t_full = None
    iters_full = None
    if 1 in ks:
        fr = pop.solve_full_ex(prob, exec_cfg=ExecConfig(solver_kw=kw))
        t_full = fr.solve_time_s
        iters_full = int(fr.res.iterations)
    for backend in backends:
        t1 = None
        for k in ks:
            if k == 1:
                t, iters = t_full, iters_full
            else:
                r = pop.solve_instance(
                    prob, SolveConfig(k=k, strategy="stratified"),
                    ExecConfig(backend=backend, solver_kw=kw))
                t, iters = r.solve_time_s, int(r.iterations.sum())
            rows.append(dict(backend=backend, k=k, solve_s=t, iters=iters))
            t1 = t1 or t
            emit(f"pop_scaling_{backend}_k{k}", t * 1e6,
                 f"speedup={t1/t:.2f}x;iters={iters}")
        # empirical exponent from the k>=2 tail (needs >= 2 points to fit)
        kk = np.array([r["k"] for r in rows
                       if r["backend"] == backend and r["k"] >= 2], float)
        tt = np.array([r["solve_s"] for r in rows
                       if r["backend"] == backend and r["k"] >= 2], float)
        if kk.size >= 2:
            expos[backend] = float(
                np.polyfit(np.log(kk), np.log(t1 / tt), 1)[0])
            emit(f"pop_scaling_exponent_{backend}", 0.0,
                 f"speedup~k^{expos[backend]:.2f}")
        else:
            # None (JSON null), not NaN — json.dump emits a non-standard
            # NaN token that strict parsers reject
            expos[backend] = None
            emit(f"pop_scaling_exponent_{backend}", 0.0,
                 f"skipped: need >=2 ks above 1, got {kk.size}")
    expo = expos[backends[0]]

    # step-engine A/B on dense stacks (fused must never lose to matvec).
    # Deliberately full-size even under run.py --fast: this is the
    # PR-over-PR tracked signal in BENCH_pop.json, so it keeps full k
    # coverage and repeat count (~3 min of the scenario's wall time).
    engine_rows = engine_sweep(ks=ks, seed=seed) if engines else []
    # ... and on REAL structured (Gavel) stacks: fused_structured vs matvec
    # (the ISSUE acceptance signal), plus the in-loop-KKT A/B
    structured_rows = (structured_engine_sweep(ks=tuple(k for k in ks
                                                        if k <= 16),
                                               n_jobs=min(n_jobs, 256),
                                               seed=seed)
                       if engines else [])
    kkt_rows = kkt_sweep(seed=seed) if engines else []

    # solver substrate vs scipy
    rng = np.random.default_rng(0)
    n, mi = 300, 200
    c = rng.normal(size=n)
    G = rng.normal(size=(mi, n))
    h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)
    with Timer() as t_sp:
        ref = linprog(c, A_ub=G, b_ub=h, bounds=(0, 1), method="highs")
    lp = LinearProgram.build(c=c, G=G, h=h, l=np.zeros(n), u=np.ones(n))
    pdhg.solve_dense(lp, max_iters=100)        # warm the jit cache
    with Timer() as t_us:
        res = pdhg.solve_dense(lp, max_iters=60_000, tol_primal=1e-6,
                               tol_gap=1e-6)
        res.x.block_until_ready()
    gap = abs(float(res.primal_obj) - ref.fun) / (1 + abs(ref.fun))
    emit("pdhg_vs_scipy", t_us.seconds * 1e6,
         f"scipy_us={t_sp.seconds*1e6:.0f};rel_obj_gap={gap:.2e};"
         f"iters={int(res.iterations)}")

    out = {"rows": rows, "exponent": expo, "exponents": expos,
           "engine_rows": engine_rows, "structured_rows": structured_rows,
           "kkt_rows": kkt_rows}
    save_json("pop_scaling", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", action="append", default=None,
                    choices=sorted(backends_mod.available_backends()),
                    help="map-step backend to sweep (repeatable; default: "
                         f"{', '.join(DEFAULT_BACKENDS)})")
    ap.add_argument("--n-jobs", type=int, default=512)
    ap.add_argument("--ks", type=int, nargs="+", default=list(DEFAULT_KS))
    ap.add_argument("--engine-sweep", action="store_true",
                    help="run ONLY the step-engine A/B (seconds-scale; "
                         "what `make bench-smoke` uses)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the engine sweep")
    ap.add_argument("--full", action="store_true",
                    help="run ONLY the paper-scale full-problem rows "
                         "(30k-demand traffic / 1k-job cluster; "
                         "fused_structured_full vs matvec — minutes-scale)")
    args = ap.parse_args(argv)
    if args.full:
        rows = full_engine_sweep()
        save_json("pop_full_engine", {"rows": rows})
        return
    if args.engine_sweep:
        if args.smoke:
            engine_sweep(ks=(1, 2, 4), n=60, mi=40, repeats=2,
                         max_iters=400)
            structured_engine_sweep(ks=(1, 2, 4), n_jobs=48, repeats=2,
                                    max_iters=400)
            kkt_sweep(k=4, n=120, mi=80, budget=600, repeats=3)
        else:
            engine_sweep(ks=tuple(args.ks))
            structured_engine_sweep(ks=tuple(k for k in args.ks if k <= 16))
            kkt_sweep()
        return
    run(n_jobs=args.n_jobs, ks=tuple(args.ks),
        backends=tuple(args.backend or DEFAULT_BACKENDS))


if __name__ == "__main__":
    main()
