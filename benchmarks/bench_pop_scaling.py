"""Paper §2.4: runtime scaling in k, per map-step execution backend.

Measures POP map-step runtime vs k on a fixed cluster-scheduling instance
and fits the empirical exponent: the paper predicts superlinear speedup
(k^(2a-1) serial; sub-problems here solve as one vmap batch, so the
observed exponent blends the k^2 variable reduction with PDHG's
iteration-count advantage on smaller, better-conditioned problems).

``--backend`` sweeps execution backends from the ``core/backends.py``
registry (default: vmap, chunked_vmap, shard_map) so the scaling curve is
recorded per backend — the data that justifies ``backend="auto"``'s
selection thresholds on each platform.

Also benchmarks the PDHG solver itself against scipy (HiGHS) on random
dense LPs — the solver-substrate sanity check.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
from scipy.optimize import linprog

from repro.core import LinearProgram, backends as backends_mod, pdhg, pop
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload
from .common import Timer, emit, save_json

DEFAULT_BACKENDS = ("vmap", "chunked_vmap", "shard_map")


def run(n_jobs: int = 512, ks=(1, 2, 4, 8, 16, 32), seed: int = 0,
        backends=DEFAULT_BACKENDS) -> dict:
    wl = make_cluster_workload(n_jobs, num_workers=(128, 128, 128), seed=seed)
    prob = GavelProblem(wl, space_sharing=True)
    kw = dict(max_iters=12_000, tol_primal=1e-4, tol_gap=1e-4)
    rows = []
    expos = {}
    # the k=1 baseline is the unpartitioned solve — backend-independent,
    # so run it once and share it across the sweep
    t_full = None
    if 1 in ks:
        _, _, t_full, _ = pop.solve_full(prob, solver_kw=kw)
    for backend in backends:
        t1 = None
        for k in ks:
            if k == 1:
                t = t_full
            else:
                t = pop.pop_solve(prob, k, strategy="stratified",
                                  backend=backend,
                                  solver_kw=kw).solve_time_s
            rows.append(dict(backend=backend, k=k, solve_s=t))
            t1 = t1 or t
            emit(f"pop_scaling_{backend}_k{k}", t * 1e6,
                 f"speedup={t1/t:.2f}x")
        # empirical exponent from the k>=2 tail (needs >= 2 points to fit)
        kk = np.array([r["k"] for r in rows
                       if r["backend"] == backend and r["k"] >= 2], float)
        tt = np.array([r["solve_s"] for r in rows
                       if r["backend"] == backend and r["k"] >= 2], float)
        if kk.size >= 2:
            expos[backend] = float(
                np.polyfit(np.log(kk), np.log(t1 / tt), 1)[0])
            emit(f"pop_scaling_exponent_{backend}", 0.0,
                 f"speedup~k^{expos[backend]:.2f}")
        else:
            # None (JSON null), not NaN — json.dump emits a non-standard
            # NaN token that strict parsers reject
            expos[backend] = None
            emit(f"pop_scaling_exponent_{backend}", 0.0,
                 f"skipped: need >=2 ks above 1, got {kk.size}")
    expo = expos[backends[0]]

    # solver substrate vs scipy
    rng = np.random.default_rng(0)
    n, mi = 300, 200
    c = rng.normal(size=n)
    G = rng.normal(size=(mi, n))
    h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)
    with Timer() as t_sp:
        ref = linprog(c, A_ub=G, b_ub=h, bounds=(0, 1), method="highs")
    lp = LinearProgram.build(c=c, G=G, h=h, l=np.zeros(n), u=np.ones(n))
    pdhg.solve_dense(lp, max_iters=100)        # warm the jit cache
    with Timer() as t_us:
        res = pdhg.solve_dense(lp, max_iters=60_000, tol_primal=1e-6,
                               tol_gap=1e-6)
        res.x.block_until_ready()
    gap = abs(float(res.primal_obj) - ref.fun) / (1 + abs(ref.fun))
    emit("pdhg_vs_scipy", t_us.seconds * 1e6,
         f"scipy_us={t_sp.seconds*1e6:.0f};rel_obj_gap={gap:.2e};"
         f"iters={int(res.iterations)}")

    out = {"rows": rows, "exponent": expo, "exponents": expos}
    save_json("pop_scaling", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", action="append", default=None,
                    choices=sorted(backends_mod.available_backends()),
                    help="map-step backend to sweep (repeatable; default: "
                         f"{', '.join(DEFAULT_BACKENDS)})")
    ap.add_argument("--n-jobs", type=int, default=512)
    ap.add_argument("--ks", type=int, nargs="+", default=[1, 2, 4, 8, 16, 32])
    args = ap.parse_args(argv)
    run(n_jobs=args.n_jobs, ks=tuple(args.ks),
        backends=tuple(args.backend or DEFAULT_BACKENDS))


if __name__ == "__main__":
    main()
