"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract and persists
JSON artifacts to experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from . import (bench_cluster_scheduling, bench_load_balancing,
                   bench_pop_scaling, bench_replication, bench_skewed_splits,
                   bench_traffic_engineering)

    suite = {
        # paper Fig. 3
        "cluster_scheduling": lambda: bench_cluster_scheduling.run(
            n_jobs=128 if args.fast else 448),
        # paper Fig. 4
        "traffic_engineering": lambda: bench_traffic_engineering.run(
            n_demands=3_000 if args.fast else 20_000),
        # paper Fig. 5
        "load_balancing": lambda: bench_load_balancing.run(
            n_shards=256 if args.fast else 1024,
            n_servers=16 if args.fast else 64),
        # paper Fig. 6
        "skewed_splits": lambda: bench_skewed_splits.run(
            n_demands=2_000 if args.fast else 10_000),
        # paper §4.3
        "replication": lambda: bench_replication.run(),
        # paper §2.4 + solver substrate
        "pop_scaling": lambda: bench_pop_scaling.run(
            n_jobs=128 if args.fast else 512),
    }
    if args.only:
        keep = set(args.only.split(","))
        suite = {k: v for k, v in suite.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suite.items():
        t0 = time.perf_counter()
        try:
            fn()
            print(f"# {name}: done in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:                                   # noqa: BLE001
            failures += 1
            print(f"# {name}: FAILED\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
