"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract and persists
JSON artifacts to experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--fast]

``--emit PATH`` additionally writes ONE machine-readable perf snapshot
(scenario -> wall-clock + the scenario's result payload, plus platform
metadata) so the perf trajectory is tracked PR-over-PR:

    PYTHONPATH=src python -m benchmarks.run --fast --emit BENCH_pop.json

The committed ``BENCH_pop.json`` at the repo root is the ``--fast``
snapshot — regenerate it with exactly that command when solver or backend
changes move the numbers.

``--check BASELINE`` compares the CURRENT run against a committed snapshot
and exits nonzero on regression (``make bench-check``):

    PYTHONPATH=src python -m benchmarks.run --fast --check BENCH_pop.json

A scenario regresses when it errors while the baseline succeeded, or when
its wall-clock exceeds ``--check-tol`` (default 2.5x) times the baseline
AND is more than 5s slower in absolute terms (small scenarios are all
jit-compile noise).  Scenarios absent from the baseline are reported as
NEW, not failed, so adding a benchmark does not break the gate.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _meta(fast: bool) -> dict:
    import jax
    return {
        "fast": fast,
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--emit", default=None, metavar="PATH",
                    help="write a machine-readable perf snapshot JSON "
                         "(scenario wall-clock + payloads + platform)")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="compare this run against a committed snapshot "
                         "(e.g. BENCH_pop.json); exit nonzero on regression")
    ap.add_argument("--check-tol", type=float, default=2.5,
                    help="wall-clock regression tolerance ratio for --check")
    args = ap.parse_args()

    from . import (bench_churn, bench_cluster_scheduling,
                   bench_load_balancing, bench_moe_placement,
                   bench_online_resolve, bench_pop_scaling,
                   bench_replication, bench_serve_scale, bench_session,
                   bench_skewed_splits, bench_traffic_engineering,
                   bench_tuning)

    suite = {
        # paper Fig. 3
        "cluster_scheduling": lambda: bench_cluster_scheduling.run(
            n_jobs=128 if args.fast else 448),
        # paper Fig. 4
        "traffic_engineering": lambda: bench_traffic_engineering.run(
            n_demands=3_000 if args.fast else 20_000),
        # paper Fig. 5
        "load_balancing": lambda: bench_load_balancing.run(
            n_shards=256 if args.fast else 1024,
            n_servers=16 if args.fast else 64),
        # paper Fig. 6
        "skewed_splits": lambda: bench_skewed_splits.run(
            n_demands=2_000 if args.fast else 10_000),
        # paper §4.3
        "replication": lambda: bench_replication.run(),
        # paper §2.4 + solver substrate (backend AND step-engine sweeps)
        "pop_scaling": lambda: bench_pop_scaling.run(
            n_jobs=128 if args.fast else 512),
        # online setting: warm-started re-solves on perturbed instances
        "online_resolve": lambda: bench_online_resolve.run(fast=args.fast),
        # churn-aware warm starts across partition changes (PopPlan layer)
        "churn": lambda: bench_churn.run(fast=args.fast),
        # the fourth scenario: MoE expert placement (registry-onboarded)
        "moe_placement": lambda: bench_moe_placement.run(
            n_experts=128 if args.fast else 512,
            n_devices=8 if args.fast else 16),
        # multi-tenant PopService session throughput (plan-cache hit rate,
        # warm fraction, steps/sec under interleaved tenants)
        "session": lambda: bench_session.run(fast=args.fast),
        # fleet scale: 10k tenants (1k fast) through the micro-batched
        # dispatcher — batching ratio, paged-cache hit rate, p50/p99
        "serve_scale": lambda: bench_serve_scale.run(fast=args.fast),
        # SLO auto-tuner: measured-curve config picks vs the static
        # default — steps/sec + realized quality at a fixed 2% SLO
        "tuning": lambda: bench_tuning.run(fast=args.fast),
    }
    if args.only:
        keep = set(args.only.split(","))
        suite = {k: v for k, v in suite.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    snapshot = {"meta": _meta(args.fast), "scenarios": {}}
    for name, fn in suite.items():
        t0 = time.perf_counter()
        try:
            payload = fn()
            wall = time.perf_counter() - t0
            snapshot["scenarios"][name] = {
                "wall_s": round(wall, 3),
                "result": payload if isinstance(payload, dict) else None,
            }
            print(f"# {name}: done in {wall:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:                                   # noqa: BLE001
            failures += 1
            snapshot["scenarios"][name] = {
                "wall_s": round(time.perf_counter() - t0, 3),
                "error": traceback.format_exc(limit=3),
            }
            print(f"# {name}: FAILED\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if args.emit:
        # NaN/Infinity -> null: strict JSON parsers reject the bare tokens
        clean = json.loads(json.dumps(snapshot, default=str),
                           parse_constant=lambda _: None)
        with open(args.emit, "w") as f:
            json.dump(clean, f, indent=1)
        print(f"# snapshot -> {args.emit}", file=sys.stderr, flush=True)
    if args.check:
        failures += _check_against_baseline(snapshot, args.check,
                                            args.check_tol,
                                            subset=bool(args.only))
    if failures:
        raise SystemExit(1)


def _check_against_baseline(snapshot: dict, baseline_path: str,
                            tol: float, subset: bool = False) -> int:
    """Compare the fresh ``snapshot`` against a committed baseline.  A
    scenario regresses when it now errors (baseline succeeded) or when it
    is both ``tol``x and >5s slower than the baseline; returns the
    regression count and prints a verdict line per scenario."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    base_sc = baseline.get("scenarios", {})
    meta = baseline.get("meta", {})
    cur_meta = snapshot["meta"]
    if (meta.get("platform") != cur_meta["platform"]
            or meta.get("fast") != cur_meta["fast"]):
        print(f"# check: baseline meta {meta} != current "
              f"{{'platform': {cur_meta['platform']!r}, "
              f"'fast': {cur_meta['fast']!r}}} — wall-clock comparison "
              "may be meaningless", file=sys.stderr, flush=True)
    regressions = 0
    for name, cur in snapshot["scenarios"].items():
        base = base_sc.get(name)
        if base is None:
            print(f"# check {name}: NEW (not in baseline)",
                  file=sys.stderr, flush=True)
            continue
        if "error" in cur and "error" not in base:
            print(f"# check {name}: REGRESSION (now fails, baseline passed)",
                  file=sys.stderr, flush=True)
            regressions += 1
            continue
        if "error" in base:
            # equally broken (or newly fixed) — wall-clock is meaningless
            verdict = "ok (fixed)" if "error" not in cur \
                else "ok (still failing in baseline too)"
            print(f"# check {name}: {verdict}", file=sys.stderr, flush=True)
            continue
        ratio = cur["wall_s"] / max(base["wall_s"], 1e-9)
        slow = (ratio > tol and cur["wall_s"] - base["wall_s"] > 5.0)
        verdict = "REGRESSION" if slow else "ok"
        print(f"# check {name}: {verdict} "
              f"({base['wall_s']:.1f}s -> {cur['wall_s']:.1f}s, "
              f"{ratio:.2f}x)", file=sys.stderr, flush=True)
        regressions += int(slow)
    if not subset:                   # --only deliberately runs a subset
        for name in base_sc:
            if name not in snapshot["scenarios"]:
                print(f"# check {name}: MISSING from current run — "
                      "REGRESSION", file=sys.stderr, flush=True)
                regressions += 1
    return regressions


if __name__ == "__main__":
    main()
