"""Fleet-scale serving sweep: 10k tenants through the micro-batched
dispatcher (1k under ``--fast``).

The serving story at scale has three claims, and this benchmark measures
all three on one ``PopService(dispatch=..., max_resident=...)``:

1. **Cross-tenant coalescing pays.**  Sixteen client threads drive
   same-shaped traffic tenants concurrently; the dispatcher stacks their
   sub-problem batches into shared ``solve_stacked`` launches.  Reported
   as ``batching_ratio`` (requests served per launch; > 1 means
   coalescing is happening) and ``lanes_per_launch``.
2. **Paging keeps memory bounded without losing warm state.**  With
   ``max_resident`` far below the tenant count, cold tenants' warm
   iterates spill to packed host blobs; a revisit pass over long-evicted
   tenants measures the paged-cache hit rate (``paged_in`` per
   re-entry).
3. **The dispatcher holds its own against the synchronous path.**  A
   no-dispatch control service runs the identical warm working set
   single-threaded; the sweep reports both steps/sec figures and their
   ratio.  On a host-CPU backend the stacked lanes execute serially, so
   the honest expectation is parity-to-modest-speedup (launch-overhead
   amortization + prep/solve overlap) — the lane-parallel win needs an
   accelerator.  The gate that matters for regression tracking is the
   absolute dispatcher steps/sec against the ``session`` scenario's
   synchronous baseline.

    PYTHONPATH=src python -m benchmarks.bench_serve_scale [--fast]
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core import ExecConfig, SolveConfig
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import DispatchConfig, PopService
from .common import emit, save_json

# small per-tenant problems: fleet scale is about tenant COUNT, and tiny
# instances keep the coalesced launches dominated by dispatch/paging
# machinery (the thing under test) rather than solver iterations
KW = dict(max_iters=200, tol_primal=1e-4, tol_gap=1e-4)
SOLVE = SolveConfig(k=2)
EXEC = ExecConfig(solver_kw=KW)
N_TEMPLATES = 4
# 8 concurrent clients: enough outstanding requests to fill micro-batch
# windows, few enough that GIL-bound host staging doesn't self-contend
CLIENT_THREADS = 8


def _templates():
    """A few size-identical traffic topologies.  Same node/edge/demand
    counts mean identical bare lane layouts across templates, so tenants
    built from ANY of them share one coalesce key (ELL path widths may
    differ per seed — ``concat_stacks`` pads those to the group max)."""
    out = []
    for t in range(N_TEMPLATES):
        topo = make_topology(20, 40, seed=t)
        pairs, dem = make_demands(topo, 24, seed=t)
        pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=t)
        out.append(TrafficProblem(topo, pairs, dem, pe))
    return out


def _instance(templates, i: int, scale: float) -> TrafficProblem:
    tpl = templates[i % len(templates)]
    return TrafficProblem(tpl.topo, tpl.pairs, tpl.demand * scale,
                          tpl.path_edges)


def _drive(svc, templates, ids, scale, *, first_visit: bool,
           threads: int = CLIENT_THREADS):
    """Step every tenant in ``ids`` once across ``threads`` client
    threads; returns per-step wall times.  First visits pass the instance
    and pinned configs; revisits enter by name so paged tenants restore
    through the ``session()`` re-entry path."""
    walls: list[float] = []
    lock = threading.Lock()
    shards = [ids[j::threads] for j in range(threads)]

    def worker(shard):
        local = []
        for i in shard:
            inst = _instance(templates, i, scale)
            t0 = time.perf_counter()
            if first_visit:
                sess = svc.session(f"tenant-{i}", inst, solve=SOLVE,
                                   exec=EXEC)
            else:
                sess = svc.session(f"tenant-{i}")
            sess.step(inst)
            local.append(time.perf_counter() - t0)
        with lock:
            walls.extend(local)

    ts = [threading.Thread(target=worker, args=(s,), daemon=True)
          for s in shards if s]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return walls


def _warm(svc, templates):
    """Compile every power-of-two lane bucket the sweep can hit, outside
    the timed region.  Held groups of 1..8 tenants (k=2 lanes each) land
    on padded lane counts 2..16 — with 8 client threads the drain never
    forms a larger group, so this covers the steady state exactly."""
    idx = 0
    for g in (1, 2, 4, 8):
        ths = []
        with svc.dispatcher.hold():
            def one(i):
                inst = _instance(templates, i, 1.0)
                svc.session(f"warm-{i}", inst, solve=SOLVE,
                            exec=EXEC).step(inst)
            for _ in range(g):
                t = threading.Thread(target=one, args=(idx,), daemon=True)
                t.start()
                ths.append(t)
                idx += 1
            time.sleep(0.3 + 0.05 * g)       # let every ticket enqueue
        for t in ths:
            t.join()
    for i in range(idx):
        svc.end_session(f"warm-{i}")


def run(fast: bool = False, n_tenants: int = None,
        resident: int = None) -> dict:
    n = n_tenants or (1_000 if fast else 10_000)
    resident = resident or (128 if fast else 256)
    templates = _templates()

    svc = PopService(dispatch=DispatchConfig(max_lanes=64,
                                             workers=CLIENT_THREADS),
                     max_resident=resident)
    _warm(svc, templates)

    # --- phase 1: arrival sweep — every tenant shows up once ------------
    # cold cost is dominated by per-tenant host work (plan build, session
    # registration, page-out of the LRU victim), so this phase measures
    # fleet ONBOARDING throughput and drives the paging tier to scale
    t0 = time.perf_counter()
    sweep_walls = _drive(svc, templates, list(range(n)), 1.0,
                         first_visit=True)
    sweep_s = time.perf_counter() - t0

    # --- phase 2: revisit long-evicted tenants (paged-cache hit rate) ---
    before = svc.stats()
    revisit_ids = list(range(min(2 * resident, n)))
    t1 = time.perf_counter()
    revisit_walls = _drive(svc, templates, revisit_ids, 1.03,
                           first_visit=False)
    revisit_s = time.perf_counter() - t1
    after = svc.stats()

    reentries = after["session_reentries"] - before["session_reentries"]
    paged_in = after["paged_in"] - before["paged_in"]
    page_hit_rate = paged_in / max(reentries, 1)

    # --- phase 3: steady-state serving — the dispatcher's claim ---------
    # a warm resident working set stepped repeatedly by all client
    # threads: launches coalesce across tenants, plans hit, nothing pages.
    # The sync control below runs the IDENTICAL warm working set on a
    # dispatcher-less service, single-threaded — the serving loop the
    # dispatcher replaces.
    w = min(64, resident, n)
    work_ids = list(range(w))
    rounds = 3 if fast else 6
    _drive(svc, templates, work_ids, 1.05, first_visit=False)   # re-warm
    d_before = svc.dispatcher.stats()
    steady_walls: list[float] = []
    t2 = time.perf_counter()
    for r in range(rounds):
        steady_walls += _drive(svc, templates, work_ids, 1.06 + 0.01 * r,
                               first_visit=False)
    steady_s = time.perf_counter() - t2
    d_after = svc.dispatcher.stats()
    steady_launches = d_after["launches"] - d_before["launches"]
    steady_ratio = len(steady_walls) / max(steady_launches, 1)

    dstats = svc.dispatcher.stats()
    stats = svc.stats()
    svc.close()

    ctl = PopService()
    for r in range(2):                                        # jit warm-up
        _drive(ctl, templates, work_ids, 1.05, first_visit=(r == 0),
               threads=1)
    t3 = time.perf_counter()
    sync_walls: list[float] = []
    for r in range(rounds):
        sync_walls += _drive(ctl, templates, work_ids, 1.06 + 0.01 * r,
                             first_visit=False, threads=1)
    sync_s = time.perf_counter() - t3
    ctl.close()

    steps = len(sweep_walls) + len(revisit_walls) + len(steady_walls)
    arrivals_per_s = len(sweep_walls) / sweep_s
    steps_per_s = len(steady_walls) / steady_s
    sync_steps_per_s = len(sync_walls) / sync_s
    p50 = float(np.percentile(steady_walls, 50))
    p99 = float(np.percentile(steady_walls, 99))

    emit("serve_scale_steady", steady_s / max(len(steady_walls), 1) * 1e6,
         f"steps_per_sec={steps_per_s:.2f};"
         f"steady_batching_ratio={steady_ratio:.2f};"
         f"lanes_per_launch={dstats['lanes_per_launch']:.1f}")
    emit("serve_scale_sync_control", sync_s / max(len(sync_walls), 1) * 1e6,
         f"sync_steps_per_sec={sync_steps_per_s:.2f};"
         f"dispatch_speedup={steps_per_s / sync_steps_per_s:.2f}x")
    emit("serve_scale_arrivals", sweep_s / max(len(sweep_walls), 1) * 1e6,
         f"tenants={n};arrivals_per_sec={arrivals_per_s:.2f};"
         f"batching_ratio={dstats['batching_ratio']:.2f}")
    emit("serve_scale_revisit", revisit_s / max(len(revisit_walls), 1) * 1e6,
         f"page_hit_rate={page_hit_rate:.3f};paged_in={paged_in}")
    emit("serve_scale_latency_p50", p50 * 1e6, f"p99_us={p99 * 1e6:.0f}")

    out = {
        "tenants": n, "resident_cap": resident, "steps": steps,
        "client_threads": CLIENT_THREADS, "working_set": w,
        "sweep_s": round(sweep_s, 3), "revisit_s": round(revisit_s, 3),
        "steady_s": round(steady_s, 3),
        "arrivals_per_s": round(arrivals_per_s, 3),
        "steps_per_s_dispatch": round(steps_per_s, 3),
        "steps_per_s_sync": round(sync_steps_per_s, 3),
        "dispatch_speedup": round(steps_per_s / sync_steps_per_s, 3),
        "batching_ratio": round(dstats["batching_ratio"], 3),
        "steady_batching_ratio": round(steady_ratio, 3),
        "lanes_per_launch": round(dstats["lanes_per_launch"], 2),
        "coalesced_launches": dstats["coalesced_launches"],
        "launches": dstats["launches"],
        "page_hit_rate": round(page_hit_rate, 4),
        "paged_out": stats["paged_out"], "paged_in": stats["paged_in"],
        "page_restore_failures": stats["page_restore_failures"],
        "paged_bytes": stats["paged_bytes"],
        "step_latency_p50_ms": round(p50 * 1e3, 3),
        "step_latency_p99_ms": round(p99 * 1e3, 3),
    }
    save_json("serve_scale", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--tenants", type=int, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast, n_tenants=args.tenants)
