"""Paper Fig. 5: E-Store query load balancing MILP.

Relax-and-round full problem vs POP-k (server-group split) vs E-Store
greedy: shard movement + runtime + balance feasibility.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.problems.load_balancing import (LoadBalanceProblem, estore_greedy,
                                           make_shard_workload)
from .common import Timer, emit, save_json

SOLVER_KW = dict(max_iters=12_000, tol_primal=1e-4, tol_gap=1e-4)


def run(n_shards: int = 1024, n_servers: int = 64, ks=(2, 4, 8, 16),
        seed: int = 0) -> dict:
    wl = make_shard_workload(n_shards, n_servers, seed=seed)
    prob = LoadBalanceProblem(wl)
    rows = []

    full = prob.solve_full(solver_kw=SOLVER_KW)
    rows.append(dict(method="full", k=1, solve_s=full.solve_time_s,
                     movement=full.movement, max_load_dev=full.max_load_dev,
                     feasible=full.feasible))
    emit("load_balance_full", full.solve_time_s * 1e6,
         f"movement={full.movement:.1f};dev={full.max_load_dev:.3f};"
         f"feasible={full.feasible}")

    for k in ks:
        r = prob.pop_solve(k, seed=seed, solver_kw=SOLVER_KW)
        speedup = full.solve_time_s / r.solve_time_s
        rows.append(dict(method=f"pop{k}", k=k, solve_s=r.solve_time_s,
                         movement=r.movement, max_load_dev=r.max_load_dev,
                         feasible=r.feasible, speedup=speedup))
        emit(f"load_balance_pop{k}", r.solve_time_s * 1e6,
             f"speedup={speedup:.1f}x;movement={r.movement:.1f};"
             f"rel_movement={r.movement/max(full.movement,1e-9):.3f};"
             f"feasible={r.feasible}")

    with Timer() as t:
        g = estore_greedy(wl)
    ev = prob.evaluate(g)
    rows.append(dict(method="greedy", k=0, solve_s=t.seconds,
                     movement=ev["movement"],
                     max_load_dev=ev["max_load_dev"],
                     feasible=ev["load_feasible"] and ev["mem_feasible"]))
    emit("load_balance_greedy", t.seconds * 1e6,
         f"movement={ev['movement']:.1f};dev={ev['max_load_dev']:.3f};"
         f"feasible={ev['load_feasible'] and ev['mem_feasible']}")

    out = {"n_shards": n_shards, "n_servers": n_servers, "rows": rows}
    save_json("load_balancing", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-shards", type=int, default=1024)
    ap.add_argument("--n-servers", type=int, default=64)
    a = ap.parse_args()
    run(n_shards=a.n_shards, n_servers=a.n_servers)


if __name__ == "__main__":
    main()
