"""Paper Fig. 4: WAN traffic engineering on a KDL-like topology
(754 nodes / 1790 edges).  Full max-flow LP vs POP-k vs CSPF.

Paper claims: POP-64 within 1.5% of optimal flow, ~100x faster; beats CSPF.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import ExecConfig, SolveConfig, pop
from repro.problems.traffic_engineering import (TrafficProblem,
                                                cspf_heuristic, k_shortest_paths,
                                                make_demands, make_topology)
from .common import Timer, emit, save_json

SOLVER_KW = dict(max_iters=10_000, tol_primal=1e-4, tol_gap=1e-4)


def build(n_nodes=754, n_edges=1790, n_demands=20_000, n_paths=4, seed=0):
    topo = make_topology(n_nodes=n_nodes, target_edges=n_edges, seed=seed)
    pairs, dem = make_demands(topo, n_demands, seed=seed + 1)
    pe = k_shortest_paths(topo, pairs, n_paths=n_paths, max_len=64,
                          seed=seed + 2)
    return TrafficProblem(topo, pairs, dem, pe)


def run(n_demands: int = 20_000, ks=(4, 16, 64), seed: int = 0) -> dict:
    prob = build(n_demands=n_demands, seed=seed)
    rows = []

    fr = pop.solve_full_ex(prob, exec_cfg=ExecConfig(solver_kw=SOLVER_KW))
    full, t_solve = fr.alloc, fr.solve_time_s
    ev = prob.evaluate(full)
    opt_flow = ev["total_flow"]
    rows.append(dict(method="full", k=1, solve_s=t_solve, **ev))
    emit("traffic_eng_full", t_solve * 1e6,
         f"flow={opt_flow:.1f};util={ev['max_edge_util']:.3f}")

    for k in ks:
        r = pop.solve_instance(
            prob, SolveConfig(k=k, strategy="random", seed=seed),
            ExecConfig(solver_kw=SOLVER_KW))
        ev = prob.evaluate(r.alloc)
        speedup = t_solve / r.solve_time_s
        rel = ev["total_flow"] / opt_flow
        rows.append(dict(method=f"pop{k}", k=k, solve_s=r.solve_time_s,
                         speedup=speedup, rel_flow=rel, **ev))
        emit(f"traffic_eng_pop{k}", r.solve_time_s * 1e6,
             f"speedup={speedup:.1f}x;rel_flow={rel:.4f};"
             f"util={ev['max_edge_util']:.3f}")

    with Timer() as t:
        f = cspf_heuristic(prob)
    ev = prob.evaluate(f)
    rows.append(dict(method="cspf", k=0, solve_s=t.seconds, **ev))
    emit("traffic_eng_cspf", t.seconds * 1e6,
         f"flow={ev['total_flow']:.1f};rel_flow={ev['total_flow']/opt_flow:.4f}")

    out = {"n_demands": n_demands, "rows": rows, "opt_flow": opt_flow}
    save_json("traffic_engineering", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true",
                    help="5x10^5 demands (paper scale; slow on one core)")
    ap.add_argument("--n-demands", type=int, default=None)
    a = ap.parse_args()
    n = a.n_demands or (500_000 if a.paper_scale else 20_000)
    run(n_demands=n)


if __name__ == "__main__":
    main()
