"""Churn-aware warm starts: warm-vs-cold iterations under entity churn.

PR-2's warm start required the instance SHAPE to be stable; the PopPlan
layer (``core/plan.py``) remaps the previous iterates across entity
arrivals/departures instead.  This benchmark measures what that buys: for
each paper domain, a base instance is solved cold, then re-solved at
5/20/50% entity churn (that fraction of entities replaced by fresh ones,
survivors' data jittered a few percent) both COLD and WARM via
``pop_solve(warm=prev, entity_ids=...)``.

The cold control shares the warm solve's plan/grouping (the same control
``bench_online_resolve`` uses), so the measured delta is the warm start
itself, not partition luck.  Expectation: warm well under cold at <=20%
churn on all three domains, degrading gracefully toward (and possibly
past) 1.0x at 50%.

    PYTHONPATH=src python -m benchmarks.bench_churn [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core import ExecConfig, SolveConfig, pop
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload
from repro.problems.load_balancing import (LoadBalanceProblem, ShardWorkload,
                                           make_shard_workload)
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from .common import emit, save_json

CHURN_LEVELS = (0.05, 0.2, 0.5)


def _row(domain, level, cold_iters, warm_iters, warm_fraction, converged):
    ratio = warm_iters / max(cold_iters, 1)
    emit(f"churn_{domain}_{int(level * 100)}pct", ratio * 1e6,
         f"cold={cold_iters};warm={warm_iters};wf={warm_fraction:.2f}")
    return dict(churn=level, cold_iters=int(cold_iters),
                warm_iters=int(warm_iters), iter_ratio=float(ratio),
                warm_fraction=float(warm_fraction),
                converged=bool(converged))


def run_cluster(n_jobs: int = 192, k: int = 8, n_seeds: int = 3,
                num_workers: tuple = (64, 64, 64),
                solver_kw: dict | None = None) -> dict:
    # keep the fleet CONTENDED (~1 worker per job per type): with abundant
    # workers the LP is slack, both solves finish in a few restarts, and
    # the warm-vs-cold signal washes out
    kw = dict(solver_kw or dict(max_iters=20_000, tol_primal=1e-4,
                                tol_gap=1e-4))
    wl = make_cluster_workload(n_jobs, num_workers=num_workers, seed=0)
    prob = GavelProblem(wl)
    ids = np.arange(n_jobs)
    prev = pop.solve_instance(prob, SolveConfig(k=k, strategy="stratified"),
                              ExecConfig(solver_kw=kw), entity_ids=ids)
    rows = []
    for level in CHURN_LEVELS:
        cold_t = warm_t = 0
        wf = 0.0
        conv = True
        for seed in range(n_seeds):
            rng = np.random.default_rng(1_000 * seed + int(level * 100))
            n_out = int(level * n_jobs)
            keep = np.arange(n_jobs)[n_out:]
            fresh = make_cluster_workload(n_out, num_workers=num_workers,
                                          seed=seed + 77)
            cat = lambda a, b: np.concatenate([a[keep], b])
            wl2 = dataclasses.replace(
                wl, T=cat(wl.T, fresh.T) * rng.uniform(0.98, 1.02, (n_jobs, 3)),
                w=cat(wl.w, fresh.w), z=cat(wl.z, fresh.z),
                interference=cat(wl.interference, fresh.interference),
                job_type=cat(wl.job_type, fresh.job_type))
            ids2 = np.concatenate([keep, 10_000 * (seed + 1) + np.arange(n_out)])
            prob2 = GavelProblem(wl2)
            warm = pop.solve_instance(prob2, SolveConfig(k=k, strategy="random"),
                                      ExecConfig(solver_kw=kw),
                                      warm=prev, entity_ids=ids2)
            cold = pop.solve_instance(prob2, SolveConfig(k=k),
                                      ExecConfig(solver_kw=kw), plan=warm.plan)
            cold_t += int(cold.iterations.sum())
            warm_t += int(warm.iterations.sum())
            wf += warm.warm_stats["warm_fraction"] / n_seeds
            conv &= bool(warm.converged.all())
        rows.append(_row("cluster", level, cold_t, warm_t, wf, conv))
    return dict(scenario="cluster_scheduling", n_jobs=n_jobs, k=k, rows=rows)


def run_traffic(n_demands: int = 512, k: int = 8, n_seeds: int = 3,
                solver_kw: dict | None = None) -> dict:
    kw = dict(solver_kw or dict(max_iters=20_000, tol_primal=1e-4,
                                tol_gap=1e-4))
    topo = make_topology(n_nodes=80, target_edges=190, seed=0)
    pool_n = 2 * n_demands
    pairs, size = make_demands(topo, pool_n, seed=0)
    paths = k_shortest_paths(topo, pairs, n_paths=3, max_len=24, seed=0)
    sel = np.arange(n_demands)
    prob = TrafficProblem(topo, pairs[sel], size[sel], paths[sel])
    prev = pop.solve_instance(prob, SolveConfig(k=k, strategy="random"),
                              ExecConfig(solver_kw=kw), entity_ids=sel)
    rows = []
    for level in CHURN_LEVELS:
        cold_t = warm_t = 0
        wf = 0.0
        conv = True
        for seed in range(n_seeds):
            rng = np.random.default_rng(2_000 * seed + int(level * 100))
            n_out = int(level * n_demands)
            keep = sel[n_out:]
            newcomers = rng.choice(np.arange(n_demands, pool_n), n_out,
                                   replace=False)
            sel2 = np.concatenate([keep, newcomers])
            prob2 = TrafficProblem(
                topo, pairs[sel2],
                size[sel2] * rng.uniform(0.97, 1.03, n_demands), paths[sel2])
            warm = pop.solve_instance(prob2, SolveConfig(k=k, strategy="random"),
                                      ExecConfig(solver_kw=kw),
                                      warm=prev, entity_ids=sel2)
            cold = pop.solve_instance(prob2, SolveConfig(k=k),
                                      ExecConfig(solver_kw=kw), plan=warm.plan)
            cold_t += int(cold.iterations.sum())
            warm_t += int(warm.iterations.sum())
            wf += warm.warm_stats["warm_fraction"] / n_seeds
            conv &= bool(warm.converged.all())
        rows.append(_row("traffic", level, cold_t, warm_t, wf, conv))
    return dict(scenario="traffic_engineering", n_demands=n_demands, k=k,
                rows=rows)


def run_load_balancing(n_shards: int = 512, n_servers: int = 16, k: int = 4,
                       n_seeds: int = 3,
                       solver_kw: dict | None = None) -> dict:
    kw = dict(solver_kw or dict(max_iters=12_000, tol_primal=1e-4,
                                tol_gap=1e-4))
    # eps_frac 0.15 and >=32 shards per server: keeps the zipf tails
    # FEASIBLE at every churn level — near-infeasible instances (a single
    # capped-zipf shard above the load window) grind both solves to the
    # iteration cap and drown the warm-start signal in noise
    wl = make_shard_workload(n_shards, n_servers, eps_frac=0.15, seed=0)
    wl = dataclasses.replace(wl, ids=np.arange(n_shards))
    prev = LoadBalanceProblem(wl).pop_solve(k, solver_kw=kw)
    pool = make_shard_workload(2 * n_shards, n_servers, eps_frac=0.15, seed=9)
    rows = []
    for level in CHURN_LEVELS:
        cold_t = warm_t = 0
        wf = 0.0
        for seed in range(n_seeds):
            rng = np.random.default_rng(3_000 * seed + int(level * 100))
            n_out = int(level * n_shards)
            keep = np.sort(rng.choice(n_shards, n_shards - n_out,
                                      replace=False))
            new = rng.choice(2 * n_shards, n_out, replace=False)
            wl2 = ShardWorkload(
                load=np.concatenate([wl.load[keep], pool.load[new]])
                     * rng.uniform(0.97, 1.03, n_shards),
                mem=np.concatenate([wl.mem[keep], pool.mem[new]]),
                placement=np.concatenate([prev.placement[keep],
                                          rng.integers(0, n_servers, n_out)]),
                cap=wl.cap, eps_frac=wl.eps_frac,
                ids=np.concatenate([keep, 10_000 * (seed + 1)
                                    + np.arange(n_out)]))
            prob2 = LoadBalanceProblem(wl2)
            # cold control shares the grouping (warm minus the warm start)
            cold = prob2.pop_solve(k, solver_kw=kw, warm=prev,
                                   warm_start=False)
            warm = prob2.pop_solve(k, solver_kw=kw, warm=prev)
            cold_t += cold.extra["iterations"]
            warm_t += warm.extra["iterations"]
            wf += warm.extra["warm_fraction"] / n_seeds
        rows.append(_row("lb", level, cold_t, warm_t, wf, True))
    return dict(scenario="load_balancing", n_shards=n_shards,
                n_servers=n_servers, k=k, rows=rows)


def run(fast: bool = False) -> dict:
    if fast:
        cluster = run_cluster(n_jobs=96, k=4, n_seeds=2,
                              num_workers=(32, 32, 32))
        traffic = run_traffic(n_demands=256, k=4, n_seeds=2)
        lb = run_load_balancing(n_shards=128, n_servers=16, k=4, n_seeds=2)
    else:
        cluster = run_cluster()
        traffic = run_traffic()
        lb = run_load_balancing()
    out = {"cluster": cluster, "traffic": traffic, "load_balancing": lb}
    save_json("churn", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", help="CI smoke sizes")
    args = ap.parse_args(argv)
    res = run(fast=args.fast)
    for dom, payload in res.items():
        for row in payload["rows"]:
            ok = "OK " if (row["iter_ratio"] <= 1.0 or row["churn"] > 0.2) \
                else "REGR"
            print(f"# {ok} {dom:>14s} churn={row['churn']:.2f} "
                  f"ratio={row['iter_ratio']:.2f} "
                  f"(cold={row['cold_iters']} warm={row['warm_iters']})")


if __name__ == "__main__":
    main()
