"""MoE expert placement: POP vs full vs greedy — the fourth scenario's
quality/runtime row (onboarded through the domain registry alone).

Acceptance: POP at k>=4 lands within 1.5% of the unpartitioned
``solve_full`` objective (served gate load net of migration penalty)
while running the k-lane map step; greedy serves similar load but
migrates nearly the whole expert fleet.

    PYTHONPATH=src python -m benchmarks.bench_moe_placement [--fast]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import SolveConfig
from repro.domains import (greedy_placement, make_placement_instance,
                           place_experts)
from repro.domains.moe_placement import _evaluate
from .common import Timer, emit, save_json


def run(n_experts: int = 512, n_devices: int = 16, ks=(4, 8),
        seed: int = 0) -> dict:
    inst = make_placement_instance(n_experts, n_devices, seed=seed)
    rows = []

    with Timer() as t_full:
        _, _, ev_full = place_experts(inst, solve_cfg=SolveConfig(k=1))
    rows.append(dict(method="full", k=1, solve_s=t_full.seconds,
                     **{k: v for k, v in ev_full.items()}))
    emit("moe_placement_full", t_full.seconds * 1e6,
         f"objective={ev_full['objective']:.1f};"
         f"served={ev_full['served_fraction']:.3f};"
         f"moved={ev_full['n_moved']}")

    for k in ks:
        with Timer() as t:
            _, res, ev = place_experts(
                inst, solve_cfg=SolveConfig(k=k, strategy="stratified"))
        ratio = ev["objective"] / max(ev_full["objective"], 1e-9)
        rows.append(dict(method=f"pop{k}", k=k, solve_s=t.seconds,
                         obj_ratio=ratio, backend=res.backend,
                         engine=res.engine, **{k2: v for k2, v in ev.items()}))
        emit(f"moe_placement_pop{k}", t.seconds * 1e6,
             f"obj_ratio={ratio:.4f};served={ev['served_fraction']:.3f};"
             f"moved={ev['n_moved']};speedup="
             f"{t_full.seconds/max(t.seconds, 1e-9):.1f}x")

    with Timer() as t_g:
        ev_g = _evaluate(inst, greedy_placement(inst))
    rows.append(dict(method="greedy", k=0, solve_s=t_g.seconds,
                     obj_ratio=ev_g["objective"] / max(ev_full["objective"],
                                                       1e-9),
                     **{k: v for k, v in ev_g.items()}))
    emit("moe_placement_greedy", t_g.seconds * 1e6,
         f"obj_ratio={ev_g['objective']/max(ev_full['objective'], 1e-9):.4f};"
         f"moved={ev_g['n_moved']}")

    out = {"n_experts": n_experts, "n_devices": n_devices, "rows": rows}
    save_json("moe_placement", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.fast:
        run(n_experts=128, n_devices=8)
    else:
        run()
