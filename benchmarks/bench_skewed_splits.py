"""Paper Fig. 6: skewed vs self-similar sub-problems (traffic engineering).

Skewed = all commodities sharing a source node land in the same
sub-problem; self-similar = random.  The paper shows the skewed split
loses substantial flow; replication (§4.3) is additionally evaluated on a
hot-entity variant.
"""

from __future__ import annotations

import numpy as np

from repro.core import (ExecConfig, SolveConfig, pop, skewed_partition,
                        similarity_report)
from repro.problems.traffic_engineering import cspf_heuristic
from .bench_traffic_engineering import build, SOLVER_KW
from .common import emit, save_json


def run(n_demands: int = 10_000, ks=(4, 16), seed: int = 0) -> dict:
    prob = build(n_demands=n_demands, seed=seed)
    rows = []
    fr = pop.solve_full_ex(prob, exec_cfg=ExecConfig(solver_kw=SOLVER_KW))
    full, t_full = fr.alloc, fr.solve_time_s
    opt = prob.evaluate(full)["total_flow"]

    for k in ks:
        r_rand = pop.solve_instance(
            prob, SolveConfig(k=k, strategy="random", seed=seed),
            ExecConfig(solver_kw=SOLVER_KW))
        f_rand = prob.evaluate(r_rand.alloc)["total_flow"]
        idx = skewed_partition(prob.source_groups(), k)
        r_skew = pop.solve_instance(
            prob, SolveConfig(k=k), ExecConfig(solver_kw=SOLVER_KW),
            partition_idx=idx)
        f_skew = prob.evaluate(r_skew.alloc)["total_flow"]
        sim_r = r_rand.similarity["max_mean_dist"]
        sim_s = r_skew.similarity["max_mean_dist"]
        rows.append(dict(k=k, flow_random=f_rand, flow_skewed=f_skew,
                         rel_random=f_rand / opt, rel_skewed=f_skew / opt,
                         sim_random=sim_r, sim_skewed=sim_s))
        emit(f"skew_split_k{k}", r_skew.solve_time_s * 1e6,
             f"rel_flow_random={f_rand/opt:.4f};rel_flow_skewed={f_skew/opt:.4f};"
             f"simdist_random={sim_r:.3f};simdist_skewed={sim_s:.3f}")

    out = {"opt_flow": opt, "rows": rows}
    save_json("skewed_splits", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
