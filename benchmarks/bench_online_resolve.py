"""Online re-solves: cold vs warm-started POP on perturbed instances.

The paper's motivating setting is ONLINE: schedulers re-allocate every few
minutes as measured throughputs drift, balancers re-place shards as loads
shift.  Consecutive instances are tiny perturbations of each other, so the
previous solution is an excellent starting iterate — PDHG warm-starting
(``pop_solve(..., warm=prev)`` / ``LoadBalanceProblem.pop_solve(...,
warm=prev)``) should cut iteration counts by well over half at equal
solution quality.

Two scenarios, both measured as (cold re-solve, warm re-solve) on the SAME
perturbed instance with the SAME partition:

* cluster scheduling — Gavel LP, throughputs perturbed ±``perturb``
* load balancing     — §3.3 MILP relaxation, shard loads perturbed and the
  placement advanced to the previous solve's output (a real tick)

Timings use the jit-cached map solver (``backends.make_map_solver``), so
the cold/warm wall-clock delta is solver work, not retracing.

    PYTHONPATH=src python -m benchmarks.bench_online_resolve [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core import ExecConfig, SolveConfig, pop
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload
from repro.problems.load_balancing import LoadBalanceProblem, make_shard_workload
from .common import emit, save_json


def run_cluster(n_jobs: int = 256, k: int = 8, perturb: float = 0.03,
                n_rounds: int = 3, seed: int = 0,
                solver_kw: dict | None = None) -> dict:
    """Gavel scheduling rounds: round 0 cold, then ``n_rounds`` perturbed
    re-solves, each done both cold and warm on the identical instance."""
    kw = dict(solver_kw or dict(max_iters=20_000, tol_primal=1e-4,
                                tol_gap=1e-4))
    rng = np.random.default_rng(seed + 1000)
    wl = make_cluster_workload(n_jobs, num_workers=(64, 64, 64), seed=seed)
    prob = GavelProblem(wl, space_sharing=False)
    prev = pop.solve_instance(prob, SolveConfig(k=k, strategy="stratified"),
                              ExecConfig(solver_kw=kw))
    rows = [dict(round=0, mode="cold", solve_s=prev.solve_time_s,
                 iters=int(prev.iterations.sum()),
                 converged=bool(prev.converged.all()))]
    for rnd in range(1, n_rounds + 1):
        wl = dataclasses.replace(
            wl, T=wl.T * rng.uniform(1 - perturb, 1 + perturb, wl.T.shape))
        prob = GavelProblem(wl, space_sharing=False)
        cold = pop.solve_instance(prob, SolveConfig(k=k),
                                  ExecConfig(solver_kw=kw),
                                  partition_idx=prev.idx)
        warm = pop.solve_instance(prob, SolveConfig(k=k, strategy="random"),
                                  ExecConfig(solver_kw=kw), warm=prev)
        for mode, r in (("cold", cold), ("warm", warm)):
            rows.append(dict(round=rnd, mode=mode, solve_s=r.solve_time_s,
                             iters=int(r.iterations.sum()),
                             converged=bool(r.converged.all())))
        emit(f"online_cluster_round{rnd}_cold", cold.solve_time_s * 1e6,
             f"iters={int(cold.iterations.sum())}")
        emit(f"online_cluster_round{rnd}_warm", warm.solve_time_s * 1e6,
             f"iters={int(warm.iterations.sum())};"
             f"iter_ratio={warm.iterations.sum()/max(cold.iterations.sum(),1):.2f}")
        prev = warm
    return dict(scenario="cluster_scheduling", n_jobs=n_jobs, k=k,
                perturb=perturb, rows=rows)


def run_load_balancing(n_shards: int = 512, n_servers: int = 32, k: int = 4,
                       perturb: float = 0.05, n_rounds: int = 3,
                       seed: int = 0, solver_kw: dict | None = None) -> dict:
    """Balancer ticks: loads drift, the placement advances to the previous
    output, and each tick is re-solved cold and warm."""
    kw = dict(solver_kw or dict(max_iters=12_000, tol_primal=1e-4,
                                tol_gap=1e-4))
    rng = np.random.default_rng(seed + 2000)
    wl = make_shard_workload(n_shards, n_servers, seed=seed)
    prev = LoadBalanceProblem(wl).pop_solve(k, solver_kw=kw)
    rows = [dict(round=0, mode="cold", solve_s=prev.solve_time_s,
                 iters=prev.extra["iterations"],
                 movement=prev.movement, feasible=prev.feasible)]
    for rnd in range(1, n_rounds + 1):
        wl = dataclasses.replace(
            wl,
            load=wl.load * rng.uniform(1 - perturb, 1 + perturb, wl.load.shape),
            placement=prev.placement)
        prob = LoadBalanceProblem(wl)
        # cold control reuses the previous grouping (warm minus the warm
        # start) so both solves factor the instance identically
        cold = prob.pop_solve(k, solver_kw=kw, warm=prev, warm_start=False)
        warm = prob.pop_solve(k, solver_kw=kw, warm=prev)
        for mode, r in (("cold", cold), ("warm", warm)):
            rows.append(dict(round=rnd, mode=mode, solve_s=r.solve_time_s,
                             iters=r.extra["iterations"],
                             movement=r.movement, feasible=r.feasible))
        emit(f"online_lb_round{rnd}_cold", cold.solve_time_s * 1e6,
             f"iters={cold.extra['iterations']}")
        emit(f"online_lb_round{rnd}_warm", warm.solve_time_s * 1e6,
             f"iters={warm.extra['iterations']};"
             f"iter_ratio={warm.extra['iterations']/max(cold.extra['iterations'],1):.2f}")
        prev = warm
    return dict(scenario="load_balancing", n_shards=n_shards,
                n_servers=n_servers, k=k, perturb=perturb, rows=rows)


def run(fast: bool = False) -> dict:
    if fast:
        cluster = run_cluster(n_jobs=96, k=4, n_rounds=2)
        lb = run_load_balancing(n_shards=128, n_servers=16, k=4, n_rounds=2)
    else:
        cluster = run_cluster()
        lb = run_load_balancing()
    out = {"cluster": cluster, "load_balancing": lb}
    save_json("online_resolve", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", help="CI smoke sizes")
    args = ap.parse_args(argv)
    run(fast=args.fast)


if __name__ == "__main__":
    main()
