"""Multi-tenant PopService session throughput.

One service, several tenants across all four registered domains, steps
interleaved (the serving pattern: every tenant's instance drifts each
round, one churns periodically).  Reports steps/sec after the warmup
round, p50/p99 step latency, the plan-cache hit rate, and the mean warm
fraction — the observability the session layer added, aggregated by the
service itself.

A fault-injection phase (``repro.analysis.faults``) then drives one
tenant through the degradation ladder — poisoned warm iterates, a dropped
plan, a deadline under inflated solve rates — and reports the
degraded/recovered/fallback counters plus fault-step latency, so the
robustness layer's overhead and behavior are tracked PR-over-PR alongside
the happy path.

    PYTHONPATH=src python -m benchmarks.bench_session [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import ExecConfig, SolveConfig
from repro.domains import (BalanceInstance, GavelInstance,
                           make_placement_instance)
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import PopService
from .common import emit, save_json


def _tenants(fast: bool, rng):
    """(name, first instance, drift fn, SolveConfig, ExecConfig) per
    tenant — two traffic nets, a scheduler fleet, a balancer, an MoE
    fleet: the interleaved-tenant mix a serving-side PopService sees."""
    kw = dict(max_iters=1_500 if fast else 4_000, tol_primal=1e-4,
              tol_gap=1e-4)
    n_dem = 200 if fast else 1_000
    n_jobs = 48 if fast else 128
    n_groups = 40 if fast else 96
    out = []

    for t in range(2):
        topo = make_topology(16, 36, seed=t)
        pairs, dem = make_demands(topo, n_dem, seed=t)
        pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=t)
        prob = TrafficProblem(topo, pairs, dem, pe)

        def drift_traffic(inst, rng=rng):
            return TrafficProblem(
                inst.topo, inst.pairs,
                inst.demand * rng.uniform(0.97, 1.03, inst.demand.shape[0]),
                inst.path_edges)
        out.append((f"net-{t}", prob, drift_traffic,
                    SolveConfig(k=4, strategy="stratified"),
                    ExecConfig(solver_kw=kw)))

    wl = make_cluster_workload(n_jobs, seed=7)
    ginst = GavelInstance(wl, job_ids=np.arange(n_jobs))

    def drift_gavel(inst, rng=rng):
        wl2 = dataclasses.replace(
            inst.wl, T=inst.wl.T * rng.uniform(0.95, 1.05, inst.wl.T.shape))
        return GavelInstance(wl2, job_ids=inst.job_ids)
    out.append(("fleet", ginst, drift_gavel,
                SolveConfig(k=4, strategy="stratified", min_per_sub=8),
                ExecConfig(solver_kw=kw)))

    binst = BalanceInstance(load=rng.uniform(1, 8, n_groups), n_targets=8,
                            ids=np.arange(n_groups), eps_frac=0.25)

    def drift_balance(inst, rng=rng):
        # periodic churn: 10% of groups finish, fresh ones arrive
        n = inst.load.shape[0]
        n_churn = n // 10
        keep = np.arange(n_churn, n)
        return BalanceInstance(
            load=np.concatenate([inst.load[keep] * rng.uniform(0.97, 1.03,
                                                               keep.size),
                                 rng.uniform(1, 8, n_churn)]),
            n_targets=inst.n_targets, eps_frac=inst.eps_frac,
            ids=np.concatenate([inst.ids[keep],
                                inst.ids.max() + 1 + np.arange(n_churn)]))
    out.append(("balancer", binst, drift_balance, SolveConfig(k=2),
                ExecConfig(solver_kw=dict(max_iters=1_500 if fast
                                          else 6_000))))

    minst = make_placement_instance(64 if fast else 128, 8, seed=9)
    minst.ids = np.arange(minst.n_experts)

    def drift_moe(inst, rng=rng):
        return dataclasses.replace(
            inst, load=inst.load * rng.uniform(0.95, 1.05,
                                               inst.load.shape[0]))
    out.append(("moe-fleet", minst, drift_moe,
                SolveConfig(k=4, strategy="stratified", min_per_sub=8),
                ExecConfig(solver_kw=kw)))
    return out


def run(fast: bool = False, rounds: int = None, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    rounds = rounds or (3 if fast else 6)
    service = PopService()
    tenants = _tenants(fast, rng)
    insts = {}
    for name, inst, _, solve_cfg, exec_cfg in tenants:
        service.session(name, inst, solve=solve_cfg, exec=exec_cfg)
        insts[name] = inst

    # warmup round: cold solves + jit compilation (excluded from rate)
    t0 = time.perf_counter()
    for name, inst, _, _, _ in tenants:
        service.session(name).step(inst)
    warmup_s = time.perf_counter() - t0

    # interleaved steady-state rounds: all tenants drift every round
    t1 = time.perf_counter()
    n_steps = 0
    per_tenant = {name: [] for name, *_ in tenants}
    step_walls = []
    for _ in range(rounds):
        for name, _, drift, _, _ in tenants:
            insts[name] = drift(insts[name])
            ts = time.perf_counter()
            a = service.session(name).step(insts[name])
            step_walls.append(time.perf_counter() - ts)
            per_tenant[name].append(a.solve_time_s)
            n_steps += 1
    steady_s = time.perf_counter() - t1
    p50 = float(np.percentile(step_walls, 50))
    p99 = float(np.percentile(step_walls, 99))

    stats = service.stats()
    steps_per_sec = n_steps / steady_s
    emit("session_steady_steps", steady_s / n_steps * 1e6,
         f"steps_per_sec={steps_per_sec:.2f};"
         f"plan_hit_rate={stats['plan_hit_rate']:.2f};"
         f"warm_fraction={stats['warm_fraction_mean']:.3f}")
    emit("session_step_latency_p50", p50 * 1e6, f"p99_us={p99 * 1e6:.0f}")
    emit("session_warmup_round", warmup_s / len(tenants) * 1e6,
         f"tenants={len(tenants)}")
    for name in per_tenant:
        emit(f"session_tenant_{name}",
             float(np.mean(per_tenant[name])) * 1e6,
             f"steps={len(per_tenant[name])}")

    fault = _fault_phase(service, insts, tenants)

    out = {
        "tenants": len(tenants), "rounds": rounds,
        "warmup_s": round(warmup_s, 3), "steady_s": round(steady_s, 3),
        "steps_per_sec": round(steps_per_sec, 3),
        "step_latency_p50_s": round(p50, 4),
        "step_latency_p99_s": round(p99, 4),
        "faults": fault,
        "service_stats": {k: (round(v, 4) if isinstance(v, float) else v)
                          for k, v in service.stats().items()},
        "per_tenant_mean_s": {k: round(float(np.mean(v)), 4)
                              for k, v in per_tenant.items()},
    }
    save_json("session", out)
    return out


def _fault_phase(service, insts, tenants) -> dict:
    """Push one traffic tenant down the degradation ladder and time every
    rung (docs/ROBUSTNESS.md): lane quarantine, warm-state mismatch, and a
    deadline fallback under inflated solve rates."""
    from repro.analysis import faults as fj

    name, _, drift = next((n, i, d) for n, i, d, *_ in tenants
                          if n.startswith("net"))
    sess = service.session(name)
    statuses, walls = [], []

    def _step(deadline_s=None):
        insts[name] = drift(insts[name])
        ts = time.perf_counter()
        a = sess.step(insts[name], deadline_s=deadline_s)
        walls.append(time.perf_counter() - ts)
        statuses.append(a.status)
        return a

    fj.poison_warm(sess, lanes=[1])
    _step()                                   # -> recovered (quarantine)
    fj.drop_warm_plan(sess)
    _step()                                   # -> recovered (mismatch)
    saved = dict(service._rates)
    fj.inflate_rates(service, factor=1e9)
    _step(deadline_s=0.25)                    # -> fallback (deadline)
    service._rates.clear()
    service._rates.update(saved)
    _step()                                   # -> ok (ladder exits clean)

    counts = {s: statuses.count(s)
              for s in ("ok", "degraded", "recovered", "fallback")}
    emit("session_fault_step", float(np.mean(walls)) * 1e6,
         f"recovered={counts['recovered']};fallback={counts['fallback']};"
         f"final={statuses[-1]}")
    return {"statuses": statuses, "counts": counts,
            "mean_fault_step_s": round(float(np.mean(walls)), 4)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()
    print(run(fast=args.fast, rounds=args.rounds))
