"""Paper §4.3: hot-entity replication.

Traffic engineering with a heavy-tailed demand distribution (a few
'Taylor Swift' commodities holding a large share of total demand): without
replication, the sub-problem holding a hot commodity can only allocate it
1/k of each link; with replication the hot commodity spans several
sub-problems and its sub-allocations are summed.
"""

from __future__ import annotations

import numpy as np

from repro.core import ExecConfig, SolveConfig, pop
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from .bench_traffic_engineering import SOLVER_KW
from .common import emit, save_json


def build_hot(n_demands=5_000, hot_frac=0.002, hot_boost=200.0, seed=0):
    topo = make_topology(n_nodes=200, target_edges=480, seed=seed)
    pairs, dem = make_demands(topo, n_demands, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)
    n_hot = max(1, int(hot_frac * n_demands))
    hot = rng.choice(n_demands, n_hot, replace=False)
    dem[hot] *= hot_boost
    pe = k_shortest_paths(topo, pairs, n_paths=4, max_len=48, seed=seed + 3)
    return TrafficProblem(topo, pairs, dem, pe)


def run(k: int = 16, seed: int = 0) -> dict:
    prob = build_hot(seed=seed)
    fr = pop.solve_full_ex(prob, exec_cfg=ExecConfig(solver_kw=SOLVER_KW))
    full, t_full = fr.alloc, fr.solve_time_s
    opt = prob.evaluate(full)["total_flow"]

    r_plain = pop.solve_instance(
        prob, SolveConfig(k=k, strategy="random", seed=seed),
        ExecConfig(solver_kw=SOLVER_KW))
    f_plain = prob.evaluate(r_plain.alloc)["total_flow"]

    r_rep = pop.solve_instance(
        prob, SolveConfig(k=k, strategy="random", seed=seed,
                          replicate_threshold=0.5),
        ExecConfig(solver_kw=SOLVER_KW))
    f_rep = prob.evaluate(r_rep.alloc)["total_flow"]

    emit(f"replication_off_k{k}", r_plain.solve_time_s * 1e6,
         f"rel_flow={f_plain/opt:.4f}")
    emit(f"replication_on_k{k}", r_rep.solve_time_s * 1e6,
         f"rel_flow={f_rep/opt:.4f};replicas={r_rep.replication.n_expanded}")

    out = {"opt_flow": opt, "k": k, "flow_plain": f_plain, "flow_rep": f_rep,
           "n_expanded": int(r_rep.replication.n_expanded)}
    save_json("replication", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
