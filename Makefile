# Convenience entry points (PYTHONPATH=src is set for you).
#
#   make check-imports   smoke-import every repro.* module (seconds; catches
#                        version-rot ImportErrors before any test runs)
#   make test            tier-1: check-imports + full pytest suite
#   make bench-backends  POP scaling sweep across map-step backends
#   make bench-smoke     seconds-scale bench sanity: tiny step-engine A/B
#                        (fused vs matvec) + tiny warm-vs-cold online
#                        re-solve + a 200-tenant dispatcher/paging sweep —
#                        catches perf-path breakage without the full suite
#   make bench-snapshot  full --fast suite -> BENCH_pop.json (the committed
#                        PR-over-PR perf baseline)
#   make bench-check     full --fast suite compared against the committed
#                        BENCH_pop.json; nonzero exit on regression
#   make bench-churn     churn-aware warm starts: warm-vs-cold iterations
#                        under 5/20/50% entity churn, all three domains
#   make test-conformance  ONLY the cross-engine conformance matrix
#                        (engines x map backends x domains at 1e-5, plus
#                        the in-loop-KKT bit-level gate) — the fast check
#                        after touching kernels/ or the step engines
#   make test-faults     ONLY the fault-tolerance gates: the chaos suite
#                        (divergence quarantine, deadline ladder, damaged
#                        warm state) + session checkpoint/restore incl.
#                        the cross-process restore (docs/ROBUSTNESS.md)
#   make test-api        ONLY the public-surface gates: API snapshot diff,
#                        service/session + domain-registry tests, shim
#                        bit-for-bit pins, example smoke runs
#   make api-snapshot    regenerate docs/api_surface.txt after an
#                        INTENTIONAL surface change (commit the diff)
#   make tune-smoke      seconds-scale SLO-tuner profile build (one
#                        domain, scaled probes) -> /tmp; proves the
#                        scripts/tune.py pipeline without committing
#   make test-tuning     ONLY the SLO auto-tuner suite: artifact seal,
#                        fixture-pinned planner picks, online retune
#                        under churn, service counters (docs/TUNING.md)
#   make lint-pop        popcheck static-analysis suite (host-sync,
#                        retrace, Pallas, deprecated-door, cache-key
#                        lints — docs/LINTS.md); exit 1 on findings
#                        outside popcheck_baseline.json
#   make lint-pop-baseline  snapshot today's findings into
#                        popcheck_baseline.json (accepted debt)

PY = PYTHONPATH=src python

.PHONY: test check-imports test-conformance test-api test-faults \
        test-tuning tune-smoke api-snapshot lint-pop lint-pop-baseline \
        bench-backends bench-smoke bench-snapshot bench-check bench-churn

check-imports:
	$(PY) scripts/check_imports.py

lint-pop:
	$(PY) scripts/popcheck.py

lint-pop-baseline:
	$(PY) scripts/popcheck.py --baseline

test-api:
	$(PY) -m pytest -q tests/test_api_surface.py tests/test_service.py \
	    tests/test_domains.py tests/test_compat_shims.py tests/test_examples.py

api-snapshot:
	$(PY) scripts/api_surface.py --write

test:
	sh scripts/test.sh

test-conformance:
	$(PY) -m pytest -q tests/test_engine_conformance.py

test-faults:
	$(PY) -m pytest -q tests/test_faults.py tests/test_session_checkpoint.py

test-tuning:
	$(PY) -m pytest -q tests/test_tuning.py

tune-smoke:
	$(PY) scripts/tune.py --fast --domains gavel --no-launch \
	    --no-backends --emit /tmp/pop_tune_smoke.json

bench-backends:
	$(PY) -m benchmarks.bench_pop_scaling --backend vmap --backend chunked_vmap --backend shard_map

bench-smoke:
	$(PY) -m benchmarks.bench_pop_scaling --engine-sweep --smoke
	$(PY) -m benchmarks.bench_online_resolve --fast
	$(PY) -m benchmarks.bench_serve_scale --fast --tenants 200

bench-snapshot:
	$(PY) -m benchmarks.run --fast --emit BENCH_pop.json

bench-check:
	$(PY) -m benchmarks.run --fast --check BENCH_pop.json

bench-churn:
	$(PY) -m benchmarks.bench_churn --fast
