# Convenience entry points (PYTHONPATH=src is set for you).
#
#   make check-imports   smoke-import every repro.* module (seconds; catches
#                        version-rot ImportErrors before any test runs)
#   make test            tier-1: check-imports + full pytest suite
#   make bench-backends  POP scaling sweep across map-step backends
#   make bench-smoke     seconds-scale bench sanity: tiny step-engine A/B
#                        (fused vs matvec) + tiny warm-vs-cold online
#                        re-solve — catches perf-path breakage without the
#                        full suite
#   make bench-snapshot  full --fast suite -> BENCH_pop.json (the committed
#                        PR-over-PR perf baseline)

PY = PYTHONPATH=src python

.PHONY: test check-imports bench-backends bench-smoke bench-snapshot

check-imports:
	$(PY) scripts/check_imports.py

test:
	sh scripts/test.sh

bench-backends:
	$(PY) -m benchmarks.bench_pop_scaling --backend vmap --backend chunked_vmap --backend shard_map

bench-smoke:
	$(PY) -m benchmarks.bench_pop_scaling --engine-sweep --smoke
	$(PY) -m benchmarks.bench_online_resolve --fast

bench-snapshot:
	$(PY) -m benchmarks.run --fast --emit BENCH_pop.json
