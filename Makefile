# Convenience entry points (PYTHONPATH=src is set for you).
#
#   make check-imports   smoke-import every repro.* module (seconds; catches
#                        version-rot ImportErrors before any test runs)
#   make test            tier-1: check-imports + full pytest suite
#   make bench-backends  POP scaling sweep across map-step backends

PY = PYTHONPATH=src python

.PHONY: test check-imports bench-backends

check-imports:
	$(PY) scripts/check_imports.py

test:
	sh scripts/test.sh

bench-backends:
	$(PY) -m benchmarks.bench_pop_scaling --backend vmap --backend chunked_vmap --backend shard_map
