#!/usr/bin/env python
"""popcheck: static analysis tuned to this repo's hot-path failure modes.

    python scripts/popcheck.py                  # scan src/repro, examples/,
                                                # benchmarks/; exit 1 on any
                                                # non-baselined finding
    python scripts/popcheck.py --baseline       # snapshot current findings
                                                # into popcheck_baseline.json
    python scripts/popcheck.py --rules host-sync-in-hot-path,api-drift
    python scripts/popcheck.py path/to/file.py  # scan specific paths

Rule catalog + suppression syntax: docs/LINTS.md.  The committed baseline
(popcheck_baseline.json) holds known, intentionally-tolerated findings;
`make lint-pop` fails only on NEW ones.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import (  # noqa: E402
    RULES, load_baseline, run_popcheck, write_baseline)
from repro.analysis.core import DEFAULT_SCAN_DIRS  # noqa: E402

BASELINE = REPO_ROOT / "popcheck_baseline.json"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to scan (default: {DEFAULT_SCAN_DIRS})")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--baseline", action="store_true",
                    help=f"write current findings to {BASELINE.name} "
                         "instead of failing on them")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the committed baseline (report everything)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args()

    if args.list_rules:
        for name in sorted(RULES):
            print(name)
        return 0

    paths = ([Path(p) for p in args.paths] if args.paths
             else [REPO_ROOT / d for d in DEFAULT_SCAN_DIRS])
    rules = args.rules.split(",") if args.rules else None
    baseline = {} if (args.baseline or args.no_baseline) \
        else load_baseline(BASELINE)

    findings = run_popcheck(paths, rules=rules, baseline=baseline,
                            repo_root=REPO_ROOT)

    if args.baseline:
        write_baseline(findings, BASELINE)
        print(f"popcheck: baselined {len(findings)} finding(s) "
              f"-> {BASELINE.name}")
        for f in findings:
            print(f"  {f.render()}")
        return 0

    for f in findings:
        print(f.render())
    n_rules = len(rules) if rules else len(RULES)
    if findings:
        print(f"popcheck: {len(findings)} new finding(s) across {n_rules} "
              "rule(s); fix them, suppress with '# popcheck: "
              "disable=<rule>', or re-baseline (make lint-pop-baseline)")
        return 1
    print(f"popcheck: clean ({n_rules} rule(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
