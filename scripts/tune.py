#!/usr/bin/env python
"""tune: measure quality/latency curves and emit a TuningProfile artifact.

    python scripts/tune.py                      # full sweep, all domains,
                                                # writes TUNING_profile.json
    python scripts/tune.py --fast               # scaled-down probes (CI)
    python scripts/tune.py --domains gavel,traffic
    python scripts/tune.py --emit /tmp/prof.json --seed 3
    python scripts/tune.py --no-launch --no-backends   # curves only

The emitted artifact is versioned and digest-sealed; consumers must gate
every read with ``check_profile`` (the ``profile-staleness`` lint
enforces this).  ``PopService(profile=...)`` uses it to plan sessions
against an :class:`~repro.tuning.SLOTarget`, install measured
``backend="auto"`` thresholds, and size dispatcher defaults.  Format +
planner rules: docs/TUNING.md.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.tuning import build_profile, check_profile, save_profile  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--domains", default="gavel,traffic,moe_placement",
                    help="comma-separated domain names to profile")
    ap.add_argument("--fast", action="store_true",
                    help="scaled-down probes (smaller n, fewer iters)")
    ap.add_argument("--emit", default=str(REPO_ROOT / "TUNING_profile.json"),
                    help="output path (default: TUNING_profile.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-launch", action="store_true",
                    help="skip the dispatcher launch-cost measurement")
    ap.add_argument("--no-backends", action="store_true",
                    help="skip the vmap-vs-chunked threshold measurement")
    args = ap.parse_args(argv)

    domains = tuple(d.strip() for d in args.domains.split(",") if d.strip())
    profile = build_profile(
        domains=domains, fast=args.fast, seed=args.seed,
        measure_launch=not args.no_launch,
        measure_backends=not args.no_backends,
        log=lambda msg: print(f"[tune] {msg}", flush=True))
    out = Path(args.emit)
    save_profile(profile, out)
    check_profile(profile)   # self-check the seal we just wrote
    print(f"[tune] wrote {out} ({profile.platform}, "
          f"{len(profile.domains)} domain(s), {profile.digest[:18]}...)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
