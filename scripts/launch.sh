#!/usr/bin/env bash
# Host launch preset for benchmarks and long solves (see docs/API.md):
#
#   scripts/launch.sh python -m benchmarks.run --fast
#   POP_HOST_DEVICES=8 scripts/launch.sh python -m benchmarks.bench_pop_scaling
#
# * LD_PRELOADs gperftools' tcmalloc when installed (thread-caching
#   allocator; host-side ELL packing and pytree staging are malloc-heavy)
#   and silences its large-alloc warnings — skipped cleanly when absent.
# * Forces N host XLA devices (--xla_force_host_platform_device_count) so
#   the shard_map/pmap map backends are exercised — and timed — on a
#   many-core CPU host instead of collapsing to one device.  N defaults
#   to the core count; override with POP_HOST_DEVICES.  An existing
#   XLA_FLAGS setting for the flag is respected.
# * Quiets TF/XLA C++ logging so benchmark CSV output stays parseable.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

tcmalloc="$(PYTHONPATH="${repo_root}/src" python -m benchmarks.common)"
if [[ -n "${tcmalloc}" ]]; then
    export LD_PRELOAD="${tcmalloc}${LD_PRELOAD:+:${LD_PRELOAD}}"
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi

export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

if [[ "${XLA_FLAGS:-}" != *xla_force_host_platform_device_count* ]]; then
    n="${POP_HOST_DEVICES:-$(nproc)}"
    export XLA_FLAGS="${XLA_FLAGS:+${XLA_FLAGS} }--xla_force_host_platform_device_count=${n}"
fi

export PYTHONPATH="${repo_root}/src${PYTHONPATH:+:${PYTHONPATH}}"

exec "$@"
