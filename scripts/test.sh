#!/bin/sh
# Tier-1 verify: smoke-import every repro module + popcheck lint gate
# (check_imports.py runs both — see docs/LINTS.md), then the test suite
# with src/ on PYTHONPATH (the repo has no installed package).
#
#     scripts/test.sh              # full tier-1
#     scripts/test.sh tests/test_backends.py -k padding   # args pass through
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python scripts/check_imports.py
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
