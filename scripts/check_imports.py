#!/usr/bin/env python
"""Smoke-import every ``repro.*`` module.

Catches version-rot ImportErrors (e.g. a JAX release moving ``shard_map``)
in seconds, without running a single test.  Exits non-zero and lists every
module that failed, so one run reports all the rot at once.

    python scripts/check_imports.py            # src/ inferred from repo layout
"""

from __future__ import annotations

import importlib
import sys
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"


def module_names() -> list[str]:
    """Enumerate repro.* from the filesystem, not pkgutil.walk_packages —
    the walk imports packages as it goes, so one broken ``__init__`` would
    abort the scan or silently prune a whole subtree; we want EVERY
    failure in one run."""
    names = []
    for path in sorted(SRC.glob("repro/**/*.py")):
        parts = path.relative_to(SRC).with_suffix("").parts
        if parts[-1] == "__init__":
            parts = parts[:-1]
        names.append(".".join(parts))
    return names


def main() -> int:
    sys.path.insert(0, str(SRC))
    names = module_names()
    failed = []
    for name in names:
        try:
            importlib.import_module(name)
        except Exception:
            failed.append(name)
            print(f"FAIL {name}", file=sys.stderr)
            traceback.print_exc()
    print(f"imported {len(names) - len(failed)}/{len(names)} repro modules")
    if failed:
        print("failed: " + ", ".join(failed), file=sys.stderr)
        return 1
    # the domain registry is import-time state: a clean import that lost a
    # built-in registration is as broken as an ImportError
    import repro.domains as domains
    expected = {"gavel", "traffic", "load_balance", "moe_placement"}
    missing = expected - set(domains.names())
    if missing:
        print(f"domain registry missing built-ins: {sorted(missing)}",
              file=sys.stderr)
        return 1
    print(f"domain registry: {', '.join(domains.names())}")
    # static-analysis gate: a clean import with a fresh popcheck finding
    # (docs/LINTS.md) fails the pre-flight the same way an ImportError
    # would — `make lint-pop` reproduces this standalone
    from repro.analysis import load_baseline, run_popcheck
    findings = run_popcheck(
        [SRC / "repro", REPO_ROOT / "examples", REPO_ROOT / "benchmarks"],
        baseline=load_baseline(REPO_ROOT / "popcheck_baseline.json"),
        repo_root=REPO_ROOT)
    if findings:
        for f in findings:
            print(f.render(), file=sys.stderr)
        print(f"popcheck: {len(findings)} finding(s) — fix, suppress "
              "(# popcheck: disable=<rule>) or baseline "
              "(make lint-pop-baseline); docs/LINTS.md", file=sys.stderr)
        return 1
    print("popcheck: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
